from .config import INPUT_SHAPES, InputShape, ModelConfig, n_active_params, n_params
from .model import (decode_step, forward, init_cache, init_params, lm_loss,
                    lm_worker_loss, make_batch_specs, prefill)
from .sharding import cache_pspecs, param_pspecs
