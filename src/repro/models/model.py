"""Top-level model API: loss, init, serving entry points."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import stack
from .config import ModelConfig

AUX_LOSS_WEIGHT = 0.01


def lm_loss(params, batch, cfg: ModelConfig):
    """Next-token cross entropy.

    The target-logit extraction uses a one-hot contraction over the vocab dim
    (instead of take_along_axis) so the reduction over the *model-sharded*
    vocab lowers to a partial-sum all-reduce rather than an all-gather of the
    full logits.
    """
    logits, aux = stack.forward(params, batch["tokens"], cfg)
    targets = batch["targets"]
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, V, dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ce = jnp.mean(lse - tgt)
    return ce + AUX_LOSS_WEIGHT * aux


def lm_worker_loss(cfg: ModelConfig, n_workers: int):
    """One federated worker's local LM objective: ``lm_loss / W``.

    ``lm_loss`` is a token **mean**, so dividing by the worker count makes
    the engine's global objective ``sum_m f_m`` equal the global mean token
    cross-entropy — ``exp(global_loss)`` is perplexity.  Mean convention
    also makes the loss mean-decomposable over equal microbatches, the
    contract ``AccumulatingSource`` / ``accumulate_loss_grads``
    (core/engine.py) need; pass ``scale=1.0`` to the source, the ``1/W``
    normalization already lives here.
    """
    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg) / n_workers

    return loss_fn


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStructs for one global training batch."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


init_params = stack.init_params
forward = stack.forward
prefill = stack.prefill
decode_step = stack.decode_step
init_cache = stack.init_cache
