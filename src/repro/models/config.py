"""Model & input-shape configuration.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes as :class:`InputShape`.  ``repro/configs/<arch>.py``
instantiates the exact published numbers and registers them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # attention (0 heads => attention-free)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    # dense mlp
    d_ff: int = 0
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    moe_groups_per_shard: int = 8   # token sub-groups per data shard (capacity locality)
    capacity_factor: float = 1.25
    # batch-sharded attention: when the head count is indivisible by the
    # model axis (attention weights replicated), reshard the *local batch*
    # over the model axis for the attention block so score/softmax transients
    # shrink by the axis size. Costs a [B,S,D] reshard in/out per layer.
    attn_batch_shard: bool = False
    # combine implementation: "gather" (baseline: per-token gather from the
    # expert-sharded buffer -> GSPMD all-gathers E*C*D) or "scatter" (expert-
    # side scatter-add -> GSPMD partial-scatters locally and all-reduces only
    # T*D — the optimal combine payload; see EXPERIMENTS.md §Perf).
    moe_combine: str = "gather"
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_n_groups: int = 1
    ssm_chunk: int = 128            # SSD intra-chunk quadratic block size
    # hybrid: run the shared attention block after every `attn_every` layers
    attn_every: int = 0
    # long-context: ring-buffer KV cache window for decode (0 = full cache)
    sliding_window: int = 0
    # numerics
    norm_eps: float = 1e-6
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention chunking (flash-style online softmax)
    q_chunk: int = 1024
    kv_chunk: int = 512
    # activation checkpointing on the layer scan (recompute in backward);
    # without it the backward pass stores every intra-layer intermediate of
    # every layer (e.g. the SSD chunk tensors), far beyond HBM.
    remat: bool = True
    # scan-over-layers (single HLO layer body; fast 512-device compiles).
    # False unrolls the stack in python — used by the roofline probes because
    # XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    # count (verified empirically), so exact FLOP/byte/collective counts need
    # an unrolled lowering (done at reduced depth and extrapolated).
    scan_layers: bool = True
    # frontends ([vlm]/[audio]): token ids are precomputed codebook ids (stub
    # per the carve-out); the backbone consumes ids like any LM.
    frontend: Optional[str] = None  # "vq_image" | "encodec" | None

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.ssm_n_groups * self.ssm_state

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 or self.attn_every > 0

    @property
    def is_recurrent(self) -> bool:
        """O(1)-in-seq decode state (SSM/hybrid) => long_500k is native."""
        return self.arch_type in ("ssm", "hybrid")

    def padded_vocab(self, multiple: int = 16) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def n_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init exactly)."""
    D, V = cfg.d_model, cfg.padded_vocab()
    total = V * D + D + D * V          # embed, final norm, lm head
    per_attn = 0
    if cfg.n_heads:
        hd = cfg.hd
        per_attn = D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd + cfg.n_heads * hd * D
        if cfg.qk_norm:
            per_attn += 2 * hd
    per_mlp = 3 * D * cfg.d_ff if cfg.d_ff else 0
    per_moe = (D * cfg.n_experts + cfg.n_experts * 3 * D * cfg.moe_d_ff) if cfg.n_experts else 0
    per_mamba = 0
    if cfg.ssm_state:
        di, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_n_groups
        cc = cfg.conv_channels
        per_mamba = (D * (2 * di + 2 * G * N + H)   # in_proj
                     + cfg.ssm_conv * cc + cc        # conv w+b
                     + 3 * H                         # A_log, D, dt_bias
                     + di                            # gated norm
                     + di * D)                       # out_proj

    if cfg.arch_type == "hybrid":
        total += cfg.n_layers * (per_mamba + D)      # mamba blocks + ln
        total += per_attn + per_mlp + 2 * D          # one shared attn block
    elif cfg.arch_type == "ssm":
        total += cfg.n_layers * (per_mamba + D)
    elif cfg.arch_type == "moe":
        total += cfg.n_layers * (per_attn + per_moe + 2 * D)
    else:                                            # dense / vlm / audio
        total += cfg.n_layers * (per_attn + per_mlp + 2 * D)
    return total


def n_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return n_params(cfg)
    D = cfg.d_model
    dense_moe = cfg.n_layers * (D * cfg.n_experts + cfg.n_experts * 3 * D * cfg.moe_d_ff)
    active_moe = cfg.n_layers * (D * cfg.n_experts + cfg.top_k * 3 * D * cfg.moe_d_ff)
    return n_params(cfg) - dense_moe + active_moe
