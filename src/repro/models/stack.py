"""Decoder stack: scan-over-layers assembly of blocks, per arch family.

Layers are *stacked*: block parameters carry a leading layer dim and the
forward is a single ``lax.scan`` over it (MaxText-style), so the HLO contains
one layer body regardless of depth — essential to keep 36-54-layer models
compilable on the 512-device dry-run meshes.

Hybrid (Zamba2-style) stacks scan over Mamba2 blocks and apply one *shared*
attention+MLP block (single weight set) after every ``cfg.attn_every``-th
layer via ``lax.cond``; its per-application KV caches are carried as a
stacked ``[n_shared, ...]`` array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from .attention import (attention_forward, decode_attention, init_attention,
                        init_kv_cache)
from .config import ModelConfig
from .layers import init_mlp, normal_init, rms_norm, swiglu
from .mamba2 import (init_mamba2, init_mamba_cache, mamba2_decode,
                     mamba2_forward)
from .moe import init_moe, moe_forward, moe_forward_dense


# ---------------------------------------------------------------------------
# Block initializers
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
         "attn": init_attention(k1, cfg, dtype)}
    if cfg.n_experts:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_mamba_block(key, cfg: ModelConfig, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "mamba": init_mamba2(key, cfg, dtype)}


def init_params(key, cfg: ModelConfig):
    dtype = cfg.param_dtype
    V, D = cfg.padded_vocab(), cfg.d_model
    k_embed, k_head, k_blocks, k_shared = jax.random.split(key, 4)
    params = {
        "embed": normal_init(k_embed, (V, D), 1.0, dtype),
        "final_norm": jnp.zeros((D,), jnp.float32),
        "lm_head": normal_init(k_head, (D, V), D ** -0.5, dtype),
    }
    keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        params["blocks"] = jax.vmap(lambda k: init_attn_block(k, cfg, dtype))(keys)
    elif cfg.arch_type == "ssm":
        params["blocks"] = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(keys)
    elif cfg.arch_type == "hybrid":
        params["blocks"] = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(keys)
        params["shared_attn"] = init_attn_block(k_shared, cfg, dtype)
    else:
        raise ValueError(cfg.arch_type)
    return params


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


# ---------------------------------------------------------------------------
# Block forwards (train / prefill)
# ---------------------------------------------------------------------------

def attn_block_fwd(bp, x, positions, cfg: ModelConfig, *, return_kv=False):
    """Returns (x, aux, kv)."""
    h, kv = (attention_forward(bp["attn"], rms_norm(x, bp["ln1"], cfg.norm_eps),
                               positions, cfg, return_kv=True)
             if return_kv else
             (attention_forward(bp["attn"], rms_norm(x, bp["ln1"], cfg.norm_eps),
                                positions, cfg), None))
    x = x + h
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        m, aux = moe_forward(bp["moe"], h2, cfg)
    else:
        m, aux = swiglu(h2, **bp["mlp"]), jnp.zeros((), jnp.float32)
    return x + m, aux, kv


def mamba_block_fwd(bp, x, cfg: ModelConfig, *, return_state=False):
    h, state, tail = mamba2_forward(bp["mamba"], rms_norm(x, bp["ln"], cfg.norm_eps), cfg)
    return x + h, (state, tail) if return_state else None


# ---------------------------------------------------------------------------
# Full-stack forward: training (no caches)
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig):
    """tokens:[B,S] -> (logits [B,S,V], aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    positions = jnp.arange(S)

    def maybe_remat(fn):
        return jax.checkpoint(fn) if cfg.remat else fn

    if not cfg.scan_layers or compat.needs_loop_unrolling():
        x, aux = _forward_unrolled(params, x, positions, cfg, maybe_remat)
    elif cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        @maybe_remat
        def layer(x, bp):
            y, a, _ = attn_block_fwd(bp, x, positions, cfg)
            return y, a

        def body(carry, bp):
            x, aux = carry
            x, a = layer(x, bp)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    elif cfg.arch_type == "ssm":
        @maybe_remat
        def layer(x, bp):
            y, _ = mamba_block_fwd(bp, x, cfg)
            return y

        def body(x, bp):
            return layer(x, bp), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        @maybe_remat
        def layer(x, bp, idx):
            x, _ = mamba_block_fwd(bp, x, cfg)
            def with_attn(x):
                y, _, _ = attn_block_fwd(shared, x, positions, cfg)
                return y
            return jax.lax.cond((idx + 1) % cfg.attn_every == 0, with_attn,
                                lambda x: x, x)

        def body(x, xs):
            bp, idx = xs
            return layer(x, bp, idx), None
        x, _ = jax.lax.scan(body, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.arch_type)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return logits, aux


def _layer_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _forward_unrolled(params, x, positions, cfg: ModelConfig, maybe_remat):
    """Python-unrolled stack (exact cost_analysis for roofline probes; also
    the mandatory path inside shard_map on 0.4.x jax — see
    ``compat.needs_loop_unrolling``)."""
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        bp = _layer_slice(params["blocks"], i)
        if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
            def layer(x, bp=bp):
                y, a, _ = attn_block_fwd(bp, x, positions, cfg)
                return y, a
            x, a = maybe_remat(layer)(x)
            aux = aux + a
        else:
            def layer(x, bp=bp):
                y, _ = mamba_block_fwd(bp, x, cfg)
                return y
            x = maybe_remat(layer)(x)
            if cfg.arch_type == "hybrid" and (i + 1) % cfg.attn_every == 0:
                def shared_layer(x):
                    y, _, _ = attn_block_fwd(params["shared_attn"], x,
                                             positions, cfg)
                    return y
                x = maybe_remat(shared_layer)(x)
    return x, aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        cache["attn"] = init_kv_cache(cfg, batch, max_len, cfg.n_layers)
    elif cfg.arch_type == "ssm":
        cache["mamba"] = init_mamba_cache(cfg, batch, cfg.n_layers)
    elif cfg.arch_type == "hybrid":
        cache["mamba"] = init_mamba_cache(cfg, batch, cfg.n_layers)
        cache["attn"] = init_kv_cache(cfg, batch, max_len, n_shared_applications(cfg))
    return cache


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Processes the prompt; returns (last_token_logits, cache)."""
    B, S = tokens.shape
    assert not cfg.sliding_window or S <= cfg.sliding_window, \
        "ring-buffer prefill not supported; window must cover the prompt"
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    positions = jnp.arange(S)
    cache = init_cache(cfg, B, max_len)
    Sc = jax.tree_util.tree_leaves(cache["attn"])[0].shape[2] if "attn" in cache else 0

    def place_kv(kv):
        k, v = kv
        z = jnp.zeros((B, Sc) + k.shape[2:], cfg.compute_dtype)
        return (jax.lax.dynamic_update_slice(z, k.astype(z.dtype), (0, 0, 0, 0)),
                jax.lax.dynamic_update_slice(z, v.astype(z.dtype), (0, 0, 0, 0)))

    if not cfg.scan_layers:
        x, cache = _prefill_unrolled(params, x, positions, cfg, cache, place_kv)
    elif cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(carry, bp):
            x, aux = carry
            x, a, kv = attn_block_fwd(bp, x, positions, cfg, return_kv=True)
            return (x, aux + a), place_kv(kv)
        (x, _), (ks, vs) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])
        cache["attn"] = {"k": ks, "v": vs}
    elif cfg.arch_type == "ssm":
        def body(x, bp):
            x, st = mamba_block_fwd(bp, x, cfg, return_state=True)
            return x, st
        x, (states, tails) = jax.lax.scan(body, x, params["blocks"])
        cache["mamba"] = {"ssm": states, "conv_x": tails["x"],
                          "conv_B": tails["B"], "conv_C": tails["C"]}
    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]
        n_sh = n_shared_applications(cfg)
        kz = jnp.zeros((n_sh, B, Sc, cfg.n_kv_heads, cfg.hd), cfg.compute_dtype)
        vz = jnp.zeros_like(kz)

        def body(carry, xs):
            x, ck, cv = carry
            bp, idx = xs
            x, st = mamba_block_fwd(bp, x, cfg, return_state=True)

            def with_attn(args):
                x, ck, cv = args
                y, _, kv = attn_block_fwd(shared, x, positions, cfg, return_kv=True)
                k_full, v_full = place_kv(kv)
                j = idx // cfg.attn_every
                ck = jax.lax.dynamic_update_index_in_dim(ck, k_full, j, 0)
                cv = jax.lax.dynamic_update_index_in_dim(cv, v_full, j, 0)
                return y, ck, cv

            x, ck, cv = jax.lax.cond((idx + 1) % cfg.attn_every == 0, with_attn,
                                     lambda a: a, (x, ck, cv))
            return (x, ck, cv), st

        (x, ks, vs), (states, tails) = jax.lax.scan(
            body, (x, kz, vz), (params["blocks"], jnp.arange(cfg.n_layers)))
        cache["mamba"] = {"ssm": states, "conv_x": tails["x"],
                          "conv_B": tails["B"], "conv_C": tails["C"]}
        cache["attn"] = {"k": ks, "v": vs}

    cache["pos"] = jnp.asarray(S, jnp.int32)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return logits, cache


def _prefill_unrolled(params, x, positions, cfg: ModelConfig, cache, place_kv):
    """Python-unrolled prefill (roofline probes)."""
    attn_k, attn_v, states, tx, tB, tC = [], [], [], [], [], []
    for i in range(cfg.n_layers):
        bp = _layer_slice(params["blocks"], i)
        if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
            x, _, kv = attn_block_fwd(bp, x, positions, cfg, return_kv=True)
            k, v = place_kv(kv)
            attn_k.append(k)
            attn_v.append(v)
        else:
            x, (st, tail) = mamba_block_fwd(bp, x, cfg, return_state=True)
            states.append(st)
            tx.append(tail["x"])
            tB.append(tail["B"])
            tC.append(tail["C"])
            if cfg.arch_type == "hybrid" and (i + 1) % cfg.attn_every == 0:
                x, _, kv = attn_block_fwd(params["shared_attn"], x, positions,
                                          cfg, return_kv=True)
                k, v = place_kv(kv)
                attn_k.append(k)
                attn_v.append(v)
    if attn_k:
        cache["attn"] = {"k": jnp.stack(attn_k), "v": jnp.stack(attn_v)}
    if states:
        cache["mamba"] = {"ssm": jnp.stack(states), "conv_x": jnp.stack(tx),
                          "conv_B": jnp.stack(tB), "conv_C": jnp.stack(tC)}
    return x, cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One-token decode. tokens:[B,1] -> (logits [B,1,V], new cache)."""
    pos = cache["pos"]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    new_cache = dict(cache)

    if not cfg.scan_layers:
        x, new_cache = _decode_unrolled(params, cache, x, pos, cfg)
    elif cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(x, xs):
            bp, ck, cv = xs
            h, nk, nv = decode_attention(bp["attn"],
                                         rms_norm(x, bp["ln1"], cfg.norm_eps),
                                         ck, cv, pos, cfg)
            x = x + h
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if "moe" in bp:
                m, _ = moe_forward_dense(bp["moe"], h2, cfg)
            else:
                m = swiglu(h2, **bp["mlp"])
            return x + m, (nk, nv)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"],
                                             cache["attn"]["k"], cache["attn"]["v"]))
        new_cache["attn"] = {"k": ks, "v": vs}
    elif cfg.arch_type == "ssm":
        def body(x, xs):
            bp, cslice = xs
            h, nc = mamba2_decode(bp["mamba"], rms_norm(x, bp["ln"], cfg.norm_eps),
                                  cslice, cfg)
            return x + h, nc
        x, nmamba = jax.lax.scan(body, x, (params["blocks"], cache["mamba"]))
        new_cache["mamba"] = nmamba
    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def body(carry, xs):
            x, ck, cv = carry
            bp, idx, cslice = xs
            h, nc = mamba2_decode(bp["mamba"], rms_norm(x, bp["ln"], cfg.norm_eps),
                                  cslice, cfg)
            x = x + h

            def with_attn(args):
                x, ck, cv = args
                j = idx // cfg.attn_every
                ckj = jax.lax.dynamic_index_in_dim(ck, j, 0, keepdims=False)
                cvj = jax.lax.dynamic_index_in_dim(cv, j, 0, keepdims=False)
                h, nk, nv = decode_attention(shared["attn"],
                                             rms_norm(x, shared["ln1"], cfg.norm_eps),
                                             ckj, cvj, pos, cfg)
                x = x + h
                h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
                x = x + swiglu(h2, **shared["mlp"])
                ck = jax.lax.dynamic_update_index_in_dim(ck, nk, j, 0)
                cv = jax.lax.dynamic_update_index_in_dim(cv, nv, j, 0)
                return x, ck, cv

            x, ck, cv = jax.lax.cond((idx + 1) % cfg.attn_every == 0, with_attn,
                                     lambda a: a, (x, ck, cv))
            return (x, ck, cv), nc

        (x, ks, vs), nmamba = jax.lax.scan(
            body, (x, cache["attn"]["k"], cache["attn"]["v"]),
            (params["blocks"], jnp.arange(cfg.n_layers), cache["mamba"]))
        new_cache["mamba"] = nmamba
        new_cache["attn"] = {"k": ks, "v": vs}

    new_cache["pos"] = pos + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return logits, new_cache


def _decode_unrolled(params, cache, x, pos, cfg: ModelConfig):
    """Python-unrolled decode step (roofline probes)."""
    new_cache = dict(cache)
    ks, vs, mslices = [], [], []
    n_attn_seen = 0
    for i in range(cfg.n_layers):
        bp = _layer_slice(params["blocks"], i)
        if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
            ck = cache["attn"]["k"][i]
            cv = cache["attn"]["v"][i]
            h, nk, nv = decode_attention(bp["attn"],
                                         rms_norm(x, bp["ln1"], cfg.norm_eps),
                                         ck, cv, pos, cfg)
            x = x + h
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            m = (moe_forward_dense(bp["moe"], h2, cfg)[0] if "moe" in bp
                 else swiglu(h2, **bp["mlp"]))
            x = x + m
            ks.append(nk)
            vs.append(nv)
        else:
            cs = _layer_slice(cache["mamba"], i)
            h, nc = mamba2_decode(bp["mamba"], rms_norm(x, bp["ln"], cfg.norm_eps),
                                  cs, cfg)
            x = x + h
            mslices.append(nc)
            if cfg.arch_type == "hybrid" and (i + 1) % cfg.attn_every == 0:
                j = n_attn_seen
                n_attn_seen += 1
                sh = params["shared_attn"]
                h, nk, nv = decode_attention(sh["attn"],
                                             rms_norm(x, sh["ln1"], cfg.norm_eps),
                                             cache["attn"]["k"][j],
                                             cache["attn"]["v"][j], pos, cfg)
                x = x + h
                x = x + swiglu(rms_norm(x, sh["ln2"], cfg.norm_eps), **sh["mlp"])
                ks.append(nk)
                vs.append(nv)
    if ks:
        new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    if mslices:
        new_cache["mamba"] = jax.tree.map(lambda *xs: jnp.stack(xs), *mslices)
    return x, new_cache
