"""Expert-parallel MoE: top-k router + capacity-based gather dispatch.

Sharding scheme: expert weight tensors carry a leading expert dim sharded on
the ``model`` mesh axis; token activations are replicated across ``model``
(they are batch-sharded on ``data``).  Dispatch is *gather-based* — per
(expert, slot) we compute the source token index and gather — so the HLO
contains only real expert matmuls, not the O(T*E*C) one-hot dispatch einsum
of the classic Switch formulation (which would dwarf the useful FLOPs).

Two paths:
* **capacity path** (train / prefill, S > 1): tokens grouped per batch row,
  per-expert capacity C = Tg * top_k / E * capacity_factor, overflow dropped
  (standard GShard/Switch semantics).  The combine gather over the
  expert-sharded buffer lowers to an all-gather over ``model`` under GSPMD —
  that collective is the MoE hillclimb target in EXPERIMENTS.md §Perf.
* **dense path** (decode, S == 1): every local expert is applied to every
  token and the result masked-combined with a contraction over the sharded
  expert dim (an all-reduce). With a handful of tokens per device the expert
  *weight reads* dominate decode cost regardless of routing, so this wastes
  nothing that matters while staying GSPMD-exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro import compat

from .config import ModelConfig
from .layers import normal_init


def _shard_experts(x, spec):
    """Pin a tensor's expert dim to the model axis when a mesh is ambient.

    Without this GSPMD re-shards the f32 *cotangents* of the dispatch/expert
    buffers to replicated inside the remat backward — an all-gather of
    E*C*D f32 per layer (measured: 2x 5 GiB/layer on qwen3-moe train_4k; see
    EXPERIMENTS.md §Perf). Constraints on the forward values propagate to the
    cotangents.
    """
    if compat.in_legacy_partial_auto_region():
        # 0.4.x legacy shim: the partial-auto partitioner aborts on
        # non-manual constraints inside the region; the constraint is a
        # collective-payload perf optimization, so the correct degradation
        # is a no-op (delete with the 0.4.37 CI pin)
        return x
    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no mesh context (single-device tests)
        return x


def init_moe(key, cfg: ModelConfig, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": normal_init(ks[0], (D, E), D ** -0.5, jnp.float32),
        "w_gate": normal_init(ks[1], (E, D, F), D ** -0.5, dtype),
        "w_up": normal_init(ks[2], (E, D, F), D ** -0.5, dtype),
        "w_down": normal_init(ks[3], (E, F, D), F ** -0.5, dtype),
    }


import functools


@functools.lru_cache(maxsize=None)
def _make_dispatch(S: int, dtype_name: str):
    """Gather-dispatch with a hand-written transpose.

    ``xe = x[b, src]`` (x [B,S,D], src [B,E,C] -> [B,E,C,D]; src<0 => 0).
    The autodiff transpose of this gather is a scatter with a packed 2-vector
    index layout that GSPMD partitions by REPLICATING the E-sharded updates —
    an all-gather of E*C*D f32 per layer (measured: 2x 5 GiB/layer on
    qwen3-moe train_4k). Writing the transpose ourselves in the batched
    .at[].add form lowers to partial scatters + one all-reduce of [B,S,D]
    (the pattern GSPMD gets right; see EXPERIMENTS.md §Perf).
    """
    dtype = jnp.dtype(dtype_name)

    @jax.custom_vjp
    def dispatch(x, src):
        B = x.shape[0]
        b_idx = jnp.arange(B)[:, None, None]
        xe = x[b_idx, jnp.maximum(src, 0)]
        return jnp.where((src >= 0)[..., None], xe, 0)

    def fwd(x, src):
        return dispatch(x, src), src

    def bwd(src, g):
        B, D = g.shape[0], g.shape[-1]
        b_idx = jnp.arange(B)[:, None, None]
        # re-pin the cotangent's expert sharding: inside the remat backward
        # GSPMD otherwise treats g as replicated and all-gathers it
        g = _shard_experts(g, (None, "model", None, None))
        g = jnp.where((src >= 0)[..., None], g, 0)
        dx = jnp.zeros((B, S, D), g.dtype)
        dx = dx.at[b_idx, jnp.maximum(src, 0)].add(g, mode="drop")
        return dx.astype(dtype), None

    dispatch.defvjp(fwd, bwd)
    return dispatch


def _dispatch(x, src):
    return _make_dispatch(x.shape[1], jnp.dtype(x.dtype).name)(x, src)


def _router(p, x, cfg: ModelConfig):
    """x:[..., D] -> (probs, topk weights, topk ids, aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    if compat.in_legacy_partial_auto_region():
        # 0.4.x legacy shim (delete with the 0.4.37 CI pin): top_k lowers
        # to a sort the legacy partial-auto partitioner aborts on.  K
        # static argmax+mask rounds select the same experts in the same
        # order (argmax and top_k both break ties toward the lower index).
        work = probs
        ws, ids = [], []
        for _ in range(cfg.top_k):
            idx = jnp.argmax(work, axis=-1)
            ids.append(idx.astype(jnp.int32))
            ws.append(jnp.take_along_axis(
                probs, idx[..., None], axis=-1)[..., 0])
            work = jnp.where(jax.nn.one_hot(idx, probs.shape[-1],
                                            dtype=jnp.bool_),
                             -jnp.inf, work)
        top_w = jnp.stack(ws, axis=-1)
        top_ids = jnp.stack(ids, axis=-1)
    else:
        top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    E = cfg.n_experts
    assign = jnp.sum(jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=-2)
    f_e = jnp.mean(assign, axis=tuple(range(assign.ndim - 1)))
    P_e = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = E * jnp.sum(f_e * P_e)
    return top_w, top_ids, aux


def _experts_apply(p, xe):
    """xe:[...,E,C,D] grouped per expert; batched SwiGLU."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"])
    return jnp.einsum("becf,efd->becd", h, p["w_down"])


def moe_forward_capacity(p, x, cfg: ModelConfig):
    """Train/prefill path. x:[B,S,D] -> ([B,S,D], aux_loss).

    Groups = batch rows (aligned with the data-sharded batch dim, so all
    cumsum/sort work is shard-local).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(S * K / E * cfg.capacity_factor))
    C = min(C, S)

    top_w, top_ids, aux = _router(p, x, cfg)           # [B,S,K]

    # position of each token within its expert's buffer
    assign = jnp.sum(jax.nn.one_hot(top_ids, E, dtype=jnp.int32), axis=2)  # [B,S,E]
    pos_all = jnp.cumsum(assign, axis=1) * assign - 1                      # [B,S,E]
    pos_k = jnp.take_along_axis(pos_all, top_ids, axis=2)                  # [B,S,K]
    keep = pos_k < C                                                       # overflow -> drop

    # inverse map: src[b,e,c] = token index feeding slot (e,c)
    b_idx = jnp.arange(B)[:, None, None]
    t_idx = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, K))
    src = jnp.full((B, E, C), -1, jnp.int32)
    src = src.at[b_idx, top_ids, jnp.where(keep, pos_k, C)].set(
        t_idx, mode="drop")                                                # [B,E,C]

    # gather-dispatch (x replicated over model; src sharded on E -> local)
    valid = (src >= 0)[..., None]
    xe = _dispatch(x, src).astype(x.dtype)                                 # [B,E,C,D]
    xe = _shard_experts(xe, (None, "model", None, None))

    ye = _experts_apply(p, xe)                                             # [B,E,C,D]
    ye = _shard_experts(ye, (None, "model", None, None))

    if cfg.moe_combine == "scatter":
        # expert-side scatter-add: GSPMD computes partial scatters per model
        # shard and all-reduces [B,S,D] (T*D payload, vs E*C*D for gather)
        wsrc = jnp.zeros((B, E, C), jnp.float32)
        wsrc = wsrc.at[b_idx, top_ids, jnp.where(keep, pos_k, C)].set(
            top_w * keep.astype(jnp.float32), mode="drop")
        upd = ye * wsrc[..., None].astype(ye.dtype)
        upd = jnp.where(valid, upd, 0)
        upd = _shard_experts(upd, (None, "model", None, None))
        out = jnp.zeros((B, S, D), x.dtype)
        out = out.at[b_idx, jnp.maximum(src, 0)].add(upd, mode="drop")
    else:
        # baseline: per-token gather (all-gather of the expert buffer)
        out_k = ye[b_idx, top_ids, jnp.minimum(pos_k, C - 1)]              # [B,S,K,D]
        w = (top_w * keep.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bskd,bsk->bsd", out_k, w)
    return out, aux


def moe_forward_dense(p, x, cfg: ModelConfig):
    """Decode path (S small): apply all experts, mask-combine, reduce over E."""
    top_w, top_ids, aux = _router(p, x, cfg)           # [B,S,K]
    E = cfg.n_experts
    # gate[b,s,e] = weight if e in top-k else 0
    gate = jnp.sum(jax.nn.one_hot(top_ids, E, dtype=jnp.float32)
                   * top_w[..., None], axis=2)         # [B,S,E]
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    ye = jnp.einsum("bsef,efd->bsed", h, p["w_down"])  # [B,S,E,D]
    out = jnp.einsum("bsed,bse->bsd", ye, gate.astype(x.dtype))
    return out, aux


def moe_forward(p, x, cfg: ModelConfig):
    if x.shape[1] == 1:
        return moe_forward_dense(p, x, cfg)
    return moe_forward_capacity(p, x, cfg)
