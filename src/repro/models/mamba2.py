"""Mamba2 / SSD (state-space duality) block — chunked scan + O(1) decode.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is evaluated as
a (masked, decay-weighted) attention-like quadratic form; across chunks a
linear ``lax.scan`` carries the [H, P, N] SSM state.  Decode is a single
recurrent state update — O(1) in context length, which is what makes
``long_500k`` native for the ssm/hybrid architectures.

Projection weights are kept as *separate* tensors per stream (z / x / B / C /
dt) rather than one fused ``in_proj`` so each can carry its own sharding
(the fused layout would interleave model-sharded and replicated segments in
one matrix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from .config import ModelConfig
from .layers import normal_init, rms_norm


def init_mamba2(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    di, H, N, G, K = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_n_groups, cfg.ssm_conv
    ks = jax.random.split(key, 9)
    s = D ** -0.5
    dt = jnp.exp(jax.random.uniform(ks[7], (H,)) * (jnp.log(0.1) - jnp.log(0.001))
                 + jnp.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "w_z": normal_init(ks[0], (D, di), s, dtype),
        "w_x": normal_init(ks[1], (D, di), s, dtype),
        "w_B": normal_init(ks[2], (D, G * N), s, dtype),
        "w_C": normal_init(ks[3], (D, G * N), s, dtype),
        "w_dt": normal_init(ks[4], (D, H), s, dtype),
        "conv_x": normal_init(ks[5], (K, di), K ** -0.5, dtype),
        "conv_B": normal_init(ks[6], (K, G * N), K ** -0.5, dtype),
        "conv_C": normal_init(ks[8], (K, G * N), K ** -0.5, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_b": jnp.zeros((G * N,), dtype),
        "conv_C_b": jnp.zeros((G * N,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "Dp": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": normal_init(jax.random.fold_in(key, 99), (di, D), di ** -0.5, dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv. u:[B,S,C], w:[K,C] -> [B,S,C]."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(K))
    return out + b


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD scan. x:[B,S,H,P] dt:[B,S,H] A:[H] Bm,Cm:[B,S,N] (G=1).

    Returns y:[B,S,H,P] and the final state [B,H,P,N].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A                                    # [B,nc,Q,H] log-decay per step
    cum_a = jnp.cumsum(a, axis=2)
    seg_a = cum_a[:, :, -1:]                        # total chunk decay [B,nc,1,H]

    # ---- intra-chunk (quadratic, attention-like) ----
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)      # [B,nc,Q,Q]
    # clamp: above-diagonal (i<j) exponents are positive and would inf/NaN
    # through the masking where() in the backward pass.
    dlog = jnp.minimum(cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :], 0.0)
    decay = jnp.exp(dlog)                           # [B,nc,i,j,H]
    ii, jj = jnp.meshgrid(jnp.arange(Q), jnp.arange(Q), indexing="ij")
    mask = (ii >= jj)[None, None, :, :, None]
    att = jnp.where(mask, CB[..., None] * decay * dtc[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(x.dtype), xc)

    # ---- chunk summary states ----
    # S_c = sum_j exp(seg_a - cum_a_j) * dt_j * B_j (x) x_j   -> [B,nc,H,N,P]
    w_j = jnp.exp(seg_a - cum_a) * dtc                          # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                        w_j.astype(x.dtype), Bc.astype(x.dtype), xc)

    # ---- inter-chunk recurrence over nc ----
    seg_decay = jnp.exp(seg_a[:, :, 0, :])                      # [B,nc,H]

    def scan_fn(R, xs):
        st, dec = xs                                            # [B,H,N,P], [B,H]
        R_new = R * dec[..., None, None] + st.astype(jnp.float32)
        return R_new, R                                         # emit state ENTERING chunk

    R0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    if compat.needs_loop_unrolling():
        # 0.4.x legacy shim (see compat.SUPPORTS_LOOPS_OVER_AUTO_AXES): the
        # chunk count is static and small (S / ssm_chunk), so the
        # recurrence unrolls without blowup
        R, emitted = R0, []
        for c in range(states.shape[1]):
            emitted.append(R)
            R = R * seg_decay[:, c][..., None, None] + states[:, c].astype(jnp.float32)
        Rfinal, R_in = R, jnp.stack(emitted, axis=1)            # [B,nc,H,N,P]
    else:
        Rfinal, R_in = jax.lax.scan(
            scan_fn,
            R0,
            (states.transpose(1, 0, 2, 3, 4), seg_decay.transpose(1, 0, 2)),
        )
        R_in = R_in.transpose(1, 0, 2, 3, 4)                    # [B,nc,H,N,P]

    # ---- inter-chunk contribution ----
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         Cc.astype(jnp.float32), R_in, jnp.exp(cum_a))
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, P)
    # final state: [B,H,P,N] layout for the decode cache
    return y.astype(x.dtype), Rfinal.transpose(0, 1, 3, 2)


def mamba2_forward(p, x, cfg: ModelConfig, chunk: int = 0):
    """Train/prefill path. x:[B,S,D] -> ([B,S,D], final_state, conv_tail)."""
    chunk = chunk or cfg.ssm_chunk
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ p["w_z"]
    xin = _causal_conv(x @ p["w_x"], p["conv_x"], p["conv_x_b"])
    Bm = _causal_conv(x @ p["w_B"], p["conv_B"], p["conv_B_b"])
    Cm = _causal_conv(x @ p["w_C"], p["conv_C"], p["conv_C_b"])
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xin.reshape(B, S, H, P), dt, A, Bm, Cm, chunk)
    y = y + xin.reshape(B, S, H, P) * p["Dp"][:, None].astype(x.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    # conv tail: last K-1 *pre-conv* projected inputs, for decode continuation
    K = cfg.ssm_conv
    tail = {
        "x": (x @ p["w_x"])[:, -(K - 1):],
        "B": (x @ p["w_B"])[:, -(K - 1):],
        "C": (x @ p["w_C"])[:, -(K - 1):],
    }
    return out, state, tail


def init_mamba_cache(cfg: ModelConfig, batch: int, n_blocks: int, dtype=jnp.float32):
    H, P, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    return {
        "ssm": jnp.zeros((n_blocks, batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((n_blocks, batch, K - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((n_blocks, batch, K - 1, cfg.ssm_n_groups * cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((n_blocks, batch, K - 1, cfg.ssm_n_groups * cfg.ssm_state), dtype),
    }


def _conv_step(tail, new, w, b):
    """tail:[B,K-1,C], new:[B,1,C] -> (out [B,C], new_tail)."""
    window = jnp.concatenate([tail, new.astype(tail.dtype)], axis=1)   # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return out, window[:, 1:]


def mamba2_decode(p, x, cache, cfg: ModelConfig):
    """One-token recurrent step. x:[B,1,D]; cache: one block's slice."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = (x @ p["w_z"])[:, 0]
    xin_new = x @ p["w_x"]
    B_new = x @ p["w_B"]
    C_new = x @ p["w_C"]
    xin, tail_x = _conv_step(cache["conv_x"], xin_new, p["conv_x"], p["conv_x_b"])
    Bm, tail_B = _conv_step(cache["conv_B"], B_new, p["conv_B"], p["conv_B_b"])
    Cm, tail_C = _conv_step(cache["conv_C"], C_new, p["conv_C"], p["conv_C_b"])
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((x @ p["w_dt"])[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                        # [B,H]
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    s = cache["ssm"] * dA[..., None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", s, Cm.astype(jnp.float32))   # [B,H,P]
    y = y + xh * p["Dp"][:, None]
    y = y.reshape(B, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    new_cache = {"ssm": s, "conv_x": tail_x, "conv_B": tail_B, "conv_C": tail_C}
    return out, new_cache
