"""GQA attention: chunked (flash-style) prefill/train + cached decode.

Training/prefill uses an online-softmax formulation: the query axis is split
into statically-unrolled chunks, and for each query chunk a ``lax.scan`` runs
over only the key/value chunks at or before it — so the HLO does not pay for
the upper causal triangle (≈6% waste at q_chunk=1024, instead of 2x for the
naive full-grid approach).

Decode attends one query token against a cache whose *sequence* dimension is
sharded over the ``model`` mesh axis (flash-decode style): GSPMD turns the
softmax max/sum and the PV contraction over the sharded dim into the standard
partial-reduction collectives.  This sidesteps the ``kv_heads < model-axis``
divisibility trap (e.g. 8 KV heads on a 16-way model axis).

Sliding-window decode (``cfg.sliding_window > 0``) uses a ring-buffer cache of
``window`` slots — this is what makes ``long_500k`` lowerable for the
full-attention architectures.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import compat

from .config import ModelConfig
from .layers import apply_rope, normal_init, rms_norm


def init_attention(key, cfg: ModelConfig, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s_in = D ** -0.5
    s_out = (H * hd) ** -0.5
    p = {
        "wq": normal_init(ks[0], (D, H * hd), s_in, dtype),
        "wk": normal_init(ks[1], (D, KV * hd), s_in, dtype),
        "wv": normal_init(ks[2], (D, KV * hd), s_in, dtype),
        "wo": normal_init(ks[3], (H * hd, D), s_out, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, x, positions, cfg: ModelConfig):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_causal_attention(q, k, v, q_positions, kv_positions, cfg: ModelConfig):
    """Online-softmax causal attention. q:[B,S,H,hd] k,v:[B,S,KV,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    Cq = min(cfg.q_chunk, S)
    if S % Cq:
        Cq = S                      # irregular lengths: one q chunk
    Ck = math.gcd(min(cfg.kv_chunk, Cq), Cq)
    nq = S // Cq
    assert S % Cq == 0 and Cq % Ck == 0, (S, Cq, Ck)

    out_chunks = []
    for qi in range(nq):                        # statically unrolled
        qc = q[:, qi * Cq:(qi + 1) * Cq]        # [B,Cq,H,hd]
        qp = q_positions[qi * Cq:(qi + 1) * Cq]
        n_kv = (qi + 1) * Cq // Ck              # only blocks at/below diagonal
        kc = k[:, :n_kv * Ck].reshape(B, n_kv, Ck, KV, hd).transpose(1, 0, 2, 3, 4)
        vc = v[:, :n_kv * Ck].reshape(B, n_kv, Ck, KV, hd).transpose(1, 0, 2, 3, 4)
        kp = kv_positions[:n_kv * Ck].reshape(n_kv, Ck)

        qg = qc.reshape(B, Cq, KV, G, hd)       # grouped-query layout (no kv repeat)

        def body(carry, xs):
            m, l, acc = carry                   # [B,KV,G,Cq], ..., [B,KV,G,Cq,hd]
            kj, vj, kpj = xs                    # [B,Ck,KV,hd], [Ck]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj).astype(jnp.float32) * scale
            mask = qp[:, None] >= kpj[None, :]  # causal
            if cfg.sliding_window:
                mask &= (qp[:, None] - kpj[None, :]) < cfg.sliding_window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(pexp, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pexp.astype(vj.dtype), vj).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, Cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Cq, hd), jnp.float32)
        if compat.needs_loop_unrolling():
            carry = (m0, l0, a0)
            for j in range(n_kv):
                carry, _ = body(carry, (kc[j], vc[j], kp[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kp))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        # [B,KV,G,Cq,hd] -> [B,Cq,KV,G,hd] -> [B,Cq,H,hd]
        out_chunks.append(o.transpose(0, 3, 1, 2, 4).reshape(B, Cq, H, hd))
    return jnp.concatenate(out_chunks, axis=1)


def attention_forward(p, x, positions, cfg: ModelConfig, *, return_kv: bool = False):
    """Train/prefill path. x:[B,S,D]; positions:[S]."""
    from .layers import maybe_constrain
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, positions, cfg)
    if cfg.attn_batch_shard:
        # heads indivisible by the model axis: shard the (local) batch over
        # it instead, shrinking every attention transient by the axis size
        q = maybe_constrain(q, "model", None, None, None)
        k = maybe_constrain(k, "model", None, None, None)
        v = maybe_constrain(v, "model", None, None, None)
    o = chunked_causal_attention(q, k, v, positions, positions, cfg)
    if cfg.attn_batch_shard:
        o = maybe_constrain(o, None, None, None, None)
    out = o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_blocks: int,
                  dtype=jnp.bfloat16):
    """Stacked-over-layers KV cache. Ring buffer if sliding_window set."""
    Sc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    KV, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_blocks, batch, Sc, KV, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig):
    """One-token decode. x:[B,1,D]; cache_[kv]:[B,Sc,KV,hd]; pos: scalar.

    Returns (out [B,1,D], new_k, new_v).  The cache sequence dim is expected
    to be sharded over the model axis; the softmax/PV reductions over it
    lower to partial-max/partial-sum collectives under GSPMD.
    """
    B, _, _ = x.shape
    Sc = cache_k.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, positions, cfg)
    slot = jnp.mod(pos, Sc) if cfg.sliding_window else pos
    new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, new_k.astype(q.dtype)).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(Sc) < jnp.minimum(pos + 1, Sc)   # full + ring buffer
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(new_v.dtype), new_v)
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return out, new_k, new_v
