"""Shared layers: norms, rotary embeddings, SwiGLU MLP, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def maybe_constrain(x, *spec):
    """with_sharding_constraint guarded on an ambient mesh having the axes."""
    try:
        mesh = compat.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ()) or ()
        for s in spec:
            if s is not None and s not in names:
                return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                  # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), s_in, dtype),
        "w_up": normal_init(k2, (d_model, d_ff), s_in, dtype),
        "w_down": normal_init(k3, (d_ff, d_model), s_out, dtype),
    }
