"""Parameter / cache PartitionSpec rules.

Megatron-style tensor parallelism on the ``model`` axis, with a universal
divisibility guard: a dim is sharded only when the *semantic* unit count
(heads, experts, ff, inner) divides the model-axis size; otherwise it is
replicated.  This is what lets e.g. mamba2-130m (24 SSM heads) or
musicgen-medium (24 attention heads) lower on a 16-way model axis — small
models simply don't tensor-parallelize, and that is recorded per-arch in the
dry-run output rather than papered over with silent resharding.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig


def _div(n: int, ms: int) -> bool:
    return n > 0 and n % ms == 0


def param_pspecs(cfg: ModelConfig, params, model_size: int, model_axis="model"):
    """A pytree of PartitionSpec mirroring ``params``."""
    ms = model_size
    m = model_axis
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    di = cfg.d_inner

    def rule(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        stacked = "blocks" in names          # leading layer dim
        def wrap(*spec):
            return P(*(((None,) + spec) if stacked else spec))

        if name == "embed":
            return P(m if _div(cfg.padded_vocab(), ms) else None, None)
        if name == "lm_head":
            return P(None, m if _div(cfg.padded_vocab(), ms) else None)
        if name == "final_norm":
            return P(None)
        # attention
        if name == "wq":
            return wrap(None, m if _div(H, ms) else None)
        if name in ("wk", "wv"):
            return wrap(None, m if _div(KV, ms) else None)
        if name == "wo":
            return wrap(m if _div(H, ms) else None, None)
        # dense mlp vs moe (moe tensors have a leading expert dim)
        if name in ("w_gate", "w_up"):
            if "moe" in names:
                return wrap(m if _div(cfg.n_experts, ms) else None, None, None)
            return wrap(None, m if _div(cfg.d_ff, ms) else None)
        if name == "w_down":
            if "moe" in names:
                return wrap(m if _div(cfg.n_experts, ms) else None, None, None)
            return wrap(m if _div(cfg.d_ff, ms) else None, None)
        if name == "router":
            return wrap(None, None)
        # mamba2
        if name in ("w_z", "w_x"):
            return wrap(None, m if _div(di, ms) else None)
        if name in ("conv_x",):
            return wrap(None, m if _div(di, ms) else None)
        if name in ("conv_x_b", "gate_norm"):
            return wrap(m if _div(di, ms) else None)
        if name == "w_dt":
            return wrap(None, m if _div(cfg.ssm_heads, ms) else None)
        if name == "out_proj":
            return wrap(m if _div(di, ms) else None, None)
        # small vectors: replicate
        return wrap(*([None] * (leaf.ndim - (1 if stacked else 0))))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_pspecs(cfg: ModelConfig, cache, data_size: int, model_size: int,
                 data_axis="data", model_axis="model"):
    """KV/SSM cache specs: batch on data (if divisible), seq / heads on model.

    The KV cache shards its *sequence* dim on the model axis (flash-decode);
    the mamba state shards heads when divisible.
    """
    def rule(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        if name == "pos":
            return P()
        batch = leaf.shape[1]
        d = data_axis if _div(batch, data_size) else None
        if name in ("k", "v"):           # [L,B,Sc,KV,hd]
            seq = leaf.shape[2]
            s = model_axis if _div(seq, model_size) else None
            return P(None, d, s, None, None)
        if name == "ssm":                # [L,B,H,P,N]
            h = model_axis if _div(leaf.shape[2], model_size) else None
            return P(None, d, h, None, None)
        if name.startswith("conv_"):     # [L,B,K-1,C]
            c = model_axis if (name == "conv_x" and _div(leaf.shape[3], model_size)) else None
            return P(None, d, None, c)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, cache)
