"""Phi-3.5-MoE (42B, 6.6B active) [moe]: 16 experts, top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, vocab=32064,
    n_heads=32, n_kv_heads=8, head_dim=128,
    n_experts=16, top_k=2, moe_d_ff=6400,
    rope_theta=1e4,
)
