"""Architecture registry: the 10 assigned configs + the paper's own models.

``get_config(arch_id)`` returns the exact published configuration;
``for_shape(cfg, shape)`` applies shape-conditioned adjustments (sliding
window for attention components at long_500k); ``smoke_config(cfg)`` returns
the reduced variant used by the CPU smoke tests (<=2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen3-8b": "qwen3_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "yi-6b": "yi_6b",
    "mamba2-130m": "mamba2_130m",
    "chameleon-34b": "chameleon_34b",
    "musicgen-medium": "musicgen_medium",
    "yi-9b": "yi_9b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
    "stablelm-1.6b": "stablelm_1p6b",
}

ARCH_IDS = tuple(_MODULES)

LONG_CONTEXT_WINDOW = 8192


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-conditioned config: attention components get a sliding-window
    ring-buffer cache at long_500k (full 500k dense attention is skipped per
    DESIGN.md; SSM components are O(1) in context natively)."""
    if shape.name == "long_500k" and cfg.has_attention:
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    if shape.kind == "train" and cfg.arch_type in ("dense", "vlm", "audio", "moe"):
        # keep the flash q-chunk a divisor of seq everywhere
        cfg = dataclasses.replace(cfg, q_chunk=min(cfg.q_chunk, shape.seq_len))
    return cfg


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=256,
        vocab=min(cfg.vocab, 512),
        q_chunk=32, kv_chunk=16,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(4, max(1, cfg.n_kv_heads)),
                  head_dim=32, d_ff=256 if cfg.d_ff else 0)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(2, cfg.top_k), moe_d_ff=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    return dataclasses.replace(cfg, **kw)
