"""Chameleon-34B [vlm]: early-fusion backbone over VQ image + text tokens;
the VQ-VAE image tokenizer frontend is a stub per the carve-out (token ids
are precomputed codebook indices). Uses qk-norm as in the paper.
[arXiv:2405.09818]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", arch_type="vlm",
    n_layers=48, d_model=8192, vocab=65536,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016,
    qk_norm=True, rope_theta=1e4,
    frontend="vq_image",
)
