"""Qwen3-30B-A3B [moe]: 128 experts, top-8, per-expert ffn 768.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe",
    n_layers=48, d_model=2048, vocab=151936,
    n_heads=32, n_kv_heads=4, head_dim=128,
    n_experts=128, top_k=8, moe_d_ff=768,
    qk_norm=True, rope_theta=1e6,
)
