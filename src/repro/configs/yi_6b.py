"""Yi-6B [dense]: llama-arch GQA. [arXiv:2403.04652]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense",
    n_layers=32, d_model=4096, vocab=64000,
    n_heads=32, n_kv_heads=4, head_dim=128, d_ff=11008,
    rope_theta=5e6,
)
