"""Zamba2-2.7B [hybrid]: Mamba2 backbone + one shared attention block applied
every 6 layers. [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    n_layers=54, d_model=2560, vocab=32000,
    n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6, rope_theta=1e4,
)
