"""MusicGen-medium [audio]: decoder-only over EnCodec tokens; the EnCodec
conv codec frontend is a stub per the carve-out (ids are precomputed
codebook indices; the 4 codebook streams are flattened to one — backbone
unchanged). [arXiv:2306.05284]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", arch_type="audio",
    n_layers=48, d_model=1536, vocab=2048,
    n_heads=24, n_kv_heads=24, head_dim=64, d_ff=6144,
    rope_theta=1e4,
    frontend="encodec",
)
