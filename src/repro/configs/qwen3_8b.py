"""Qwen3-8B [dense]: GQA + qk-norm. [hf:Qwen/Qwen3-8B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", arch_type="dense",
    n_layers=36, d_model=4096, vocab=151936,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=12288,
    qk_norm=True, rope_theta=1e6,
)
