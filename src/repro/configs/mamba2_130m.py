"""Mamba2-130M [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", arch_type="ssm",
    n_layers=24, d_model=768, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
