"""StableLM-2-1.6B [dense]. [hf:stabilityai/stablelm-2-1_6b]
(partial-rotary detail of the released model simplified to full rotary.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", arch_type="dense",
    n_layers=24, d_model=2048, vocab=100352,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632,
    rope_theta=1e4,
)
