"""Unified round engine: ONE implementation of the LAQ communication round.

Before this module the per-round protocol — sample -> local gradients ->
SVRG correction -> WK2 stale backprop -> lazy rule -> quantize -> aggregate
-> update — was hand-threaded three times: ``run_gradient_based`` and
``run_stochastic`` in :mod:`repro.core.simulated` plus the sharded step in
``launch/train.py``.  Every new lever (LASG rules, SVRG, stepsize
schedules) had to be wired in triplicate.  The engine factors the round
into pluggable stages so a new rule plugs in once:

* :class:`GradientSource` — where this round's per-worker gradients come
  from.  ``FullBatchSource`` (deterministic GD/QGD/LAG/LAQ: the full local
  gradient), ``MinibatchSource`` (SGD family: fold_in-keyed minibatches,
  ``(n/B)``-scaled), ``AccumulatingSource`` (the LM-scale worker: the same
  minibatch stream folded over sequential microbatches via
  :func:`accumulate_loss_grads`, with a ``per_device`` parallelism knob and
  a ``deterministic`` full-corpus mode).  The SVRG correction and the WK2
  same-sample stale
  backprop are *engine* stages expressed through the source's ``eval_at``,
  so their math lives here exactly once (:func:`apply_svrg_exact` /
  :func:`apply_svrg_streaming` / :func:`stale_side_grads` — the streaming
  variant is the sharded launch path's documented one-batch-anchor
  degradation).

* :class:`ParticipationModel` — which workers the server can reach this
  round.  ``full`` (every round, the paper's setting), ``bernoulli`` /
  ``fixed_k`` client sampling (LAG's heterogeneous-worker motivation:
  workers are intermittently available), and ``delay`` — bounded-staleness
  async execution where worker ``m`` computes its gradient at the iterate
  from ``d_m <= max_delay`` rounds ago (a replicated params history ring).
  Unavailable workers are masked **exactly like lazy skips** inside
  ``worker_update`` (clock grows, no wire bits, ``qhat`` and estimator
  state frozen), so ``CommState`` clocks, ``total_uploads`` and bits
  accounting stay correct — and the LAQ skip criterion composes with
  sampling (``benchmarks/participation_frontier.py`` measures the
  frontier).  Selected via ``StrategyConfig.participation`` /
  ``participation_p`` / ``max_delay`` / ``participation_seed``.

* the LAQ state machine itself — unchanged, in
  :mod:`repro.core.strategy` (``aggregate`` / ``worker_update``); dense
  baselines (sgd / qsgd / ssgd) run the compressor path instead.

``RoundEngine.round`` is a ``jax.lax.scan`` body; ``run`` scans it and
returns the same :class:`RunResult` the wrappers always produced.  The
wrappers in :mod:`repro.core.simulated` are thin shims over this class and
reproduce their pre-engine trajectories **bitwise** for every existing
kind x lazy_rule x grad_mode x wire_backend combination
(tests/test_engine_parity.py pins them against captured goldens).  The
stage contract — what a new source, participation model or rule must
provide — is documented in ``docs/engine.md``.

Availability semantics: the simulation still *computes* every worker's
gradient (a vmap lane costs nothing to mask, and SPMD shards cannot skip a
backprop anyway); participation governs the **wire** — who may upload,
whose state may advance.  The accounting (uploads, bits, clocks) is what
the paper's communication model measures.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adaptive import eta_at
from .compressors import qsgd_compress, ssgd_compress
from .faults import (apply_crashes, bitflip_keys, corrupt_grads,
                     corruption_mask, crash_mask)
from .quantize import dense_bits, tree_size, tree_sq_norm
from .strategy import (CommState, StrategyConfig, SvrgState, aggregate,
                       finalize_step, init_comm_state)

Pytree = object

PARTICIPATION = ("full", "bernoulli", "fixed_k", "markov", "delay")


class RunResult(NamedTuple):
    """Per-round trajectory of a simulated run (all arrays are [K]).

    ``mean_bits`` units differ by family — documented HERE, nowhere else:
    for the LAQ family it is the mean selected quantization width ``b``
    over the workers that uploaded this round (== the static width for
    fixed-bit runs, 32 for dense lazy uploads); for the sgd/qsgd/ssgd
    baselines it is mean *wire bits per coordinate* (total compressed
    payload / p), which for ssgd includes the index overhead.  ``None``
    when a caller constructs a result without the diagnostic.
    """
    params: Pytree
    loss: jax.Array          # [K] global loss per iteration
    grad_norm_sq: jax.Array  # [K]
    cum_uploads: jax.Array   # [K] cumulative communication rounds
    cum_bits: jax.Array      # [K] cumulative wire bits
    quant_err: jax.Array     # [K] max_m R_m (decay diagnostic, paper Fig. 3)
    mean_bits: Optional[jax.Array] = None


def broadcast_w(tree: Pytree, n_workers: int) -> Pytree:
    """Replicate a (replicated) pytree across a leading worker axis, f32."""
    return jax.tree.map(lambda l: jnp.broadcast_to(
        l.astype(jnp.float32), (n_workers,) + l.shape), tree)


# ---------------------------------------------------------------------------
# Gradient sources.
# ---------------------------------------------------------------------------

class FullBatchSource:
    """Deterministic full-gradient source (paper Table 2 methods).

    ``loss_fn(params, data_shard) -> scalar`` is one worker's local loss
    f_m; ``worker_data`` carries a leading worker axis W; the global
    objective is ``sum_m f_m`` (paper eq. 1).
    """
    stochastic = False

    def __init__(self, loss_fn, worker_data: Pytree):
        self.loss_fn = loss_fn
        self.worker_data = worker_data
        self.n_workers = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
        self._grad = jax.grad(loss_fn)

    def sample(self, step):
        return None

    def eval_at(self, params, thetas_w, batches):
        """Per-worker full local gradients.  ``thetas_w=None`` evaluates at
        the replicated ``params`` (the closure form the pre-engine runner
        used — kept so full-participation trajectories stay bitwise);
        otherwise at per-worker iterates (WK2 stale side, delay mode)."""
        if thetas_w is None:
            return jax.vmap(lambda d: self._grad(params, d))(self.worker_data)
        return jax.vmap(lambda t, d: self._grad(t, d))(thetas_w,
                                                       self.worker_data)

    def global_loss(self, params):
        return jnp.sum(jax.vmap(lambda d: self.loss_fn(params, d))(
            self.worker_data))

    def grad_norm_sq(self, params, grads):
        """PR-5 perf fix: the summed per-worker full gradients ARE the
        global gradient, so the record costs a reduction instead of a third
        backprop per round.  (Under ``delay`` participation the summed
        gradients are evaluated at stale iterates — the record is then the
        norm of the aggregate the server actually received.)"""
        return tree_sq_norm(jax.tree.map(lambda g: jnp.sum(g, axis=0), grads))


class MinibatchSource:
    """Minibatch gradient source (paper Table 3 methods).

    Every key derives functionally from ``(seed, stream, round, worker)``
    by ``fold_in`` — no carried split chain — so the batch stream is
    kind-stable and each worker's stream independent (determinism-
    regression-tested).  Stream 0 draws batches, stream 1 the compressor
    randomness.  Worker gradients are scaled by ``n_local / batch`` so
    ``sum_m E[g_m]`` equals the global-loss gradient.
    """
    stochastic = True

    def __init__(self, loss_fn, worker_data: Pytree, *, batch: int, seed: int):
        self.loss_fn = loss_fn
        self.worker_data = worker_data
        leaves = jax.tree_util.tree_leaves(worker_data)
        self.n_workers = leaves[0].shape[0]
        self.n_local = leaves[0].shape[1]
        self.batch = batch
        self.scale = self.n_local / batch
        self._grad = jax.grad(loss_fn)
        self._key0 = jax.random.PRNGKey(seed)
        self._worker_ids = jnp.arange(self.n_workers)

    def stream_keys(self, stream: int, step):
        ks = jax.random.fold_in(jax.random.fold_in(self._key0, stream), step)
        return jax.vmap(lambda m: jax.random.fold_in(ks, m))(self._worker_ids)

    def sample(self, step):
        def sample1(data_m, key):
            idx = jax.random.randint(key, (self.batch,), 0, self.n_local)
            return jax.tree.map(lambda x: x[idx], data_m)

        return jax.vmap(sample1)(self.worker_data, self.stream_keys(0, step))

    def eval_at(self, params, thetas_w, batches):
        """This round's minibatch gradients at per-worker iterates (the
        current params when ``thetas_w=None``; the WK2 stale iterates; the
        SVRG anchors; delay-mode stale params), f32 and ``n/B``-scaled."""
        if thetas_w is None:
            thetas_w = broadcast_w(params, self.n_workers)
        return jax.vmap(lambda t, b: jax.tree.map(
            lambda g: g.astype(jnp.float32) * self.scale,
            self._grad(t, b)))(thetas_w, batches)

    def full_local_grads(self, params):
        """Exact per-worker full local gradients (the SVRG anchor's mu;
        already on the global scale — ``loss_fn`` normalizes by N)."""
        return jax.vmap(lambda d: self._grad(params, d))(self.worker_data)

    def global_loss(self, params):
        return jnp.sum(jax.vmap(lambda d: self.loss_fn(params, d))(
            self.worker_data))

    def grad_norm_sq(self, params, grads):
        # the round's minibatch gradients are noisy estimates: the
        # diagnostic wants the TRUE gradient norm, which costs its own
        # (full-data) backprop here — the full-batch source reuses its
        # exact gradients instead
        return tree_sq_norm(jax.grad(self.global_loss)(params))


def accumulate_loss_grads(loss_fn, params, microbatches, *, unroll=False):
    """Fold ``(loss, grad)`` over a leading microbatch axis in one scan — the
    levanter ``accumulate_gradients_sharded`` idiom: per-microbatch
    ``value_and_grad`` with an f32 running *mean* (``acc + x / n``), so the
    peak activation memory is one microbatch's backprop regardless of the
    logical batch size.

    ``loss_fn(params, microbatch) -> scalar`` must be **mean-convention**
    (a per-example/per-token mean): the mean of equal-sized microbatch means
    equals the full-batch mean, so the fold reproduces the full-batch
    gradient up to f32 reduction order (one-microbatch folds are exact —
    add-zero and divide-by-one are identity in IEEE).  Shared by
    :class:`AccumulatingSource` and the sharded step's ``loss_and_grads``
    (launch/train.py), so both execution modes accumulate with identical
    arithmetic.  ``unroll=True`` replays the fold as a Python loop (the
    sharded step's probe mode, where scan bodies would be cost-counted
    once).
    """
    n = jax.tree_util.tree_leaves(microbatches)[0].shape[0]

    def body(carry, b):
        loss_acc, g_acc = carry
        l, g = jax.value_and_grad(loss_fn)(params, b)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32) / n,
                             g_acc, g)
        return (loss_acc + l / n, g_acc), None

    zero = (jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    if not unroll:
        return jax.lax.scan(body, zero, microbatches)[0]
    carry = zero
    for i in range(n):
        carry, _ = body(carry, jax.tree.map(lambda x: x[i], microbatches))
    return carry


class AccumulatingSource:
    """Gradient-accumulating minibatch source — the LM-scale worker.

    Each round every worker draws ``batch`` local examples from the SAME
    fold_in key stream as :class:`MinibatchSource` (stream 0, identical
    indices for identical ``(seed, batch)``), then folds loss+grad over
    ``accum`` sequential microbatches of ``batch / accum`` examples via
    :func:`accumulate_loss_grads` instead of one monolithic backprop.
    ``per_device`` is the parallelism knob expressed the levanter way:
    the largest number of examples evaluated at once, with
    ``accum = batch // per_device`` derived from it.

    Two contracts, both pinned by tests/test_lm_engine.py:

    * ``accum=1`` is **bit-identical** to ``MinibatchSource`` (the fold
      degenerates to add-zero / divide-by-one); ``accum>1`` matches to f32
      reduction order (pinned-ulp) for mean-convention losses.
    * ``deterministic=True`` ignores the sampler and streams the whole
      local corpus through the fold each round — full-batch LAQ (paper
      Table 2 semantics, :class:`FullBatchSource` gradients) at the
      accumulation memory profile; ``stochastic`` reports False so the
      engine treats it as a deterministic method.

    ``scale`` multiplies the folded gradient; the default ``n_local/batch``
    matches ``MinibatchSource`` (sum-convention global objectives).  LM
    losses are token means with the ``1/W`` global normalization already in
    ``loss_fn`` (see ``repro.models.lm_worker_loss``) — pass ``scale=1.0``.
    """

    def __init__(self, loss_fn, worker_data: Pytree, *, batch: Optional[int] = None,
                 seed: int = 0, accum: int = 1, per_device: Optional[int] = None,
                 deterministic: bool = False, scale: Optional[float] = None):
        self.loss_fn = loss_fn
        self.worker_data = worker_data
        leaves = jax.tree_util.tree_leaves(worker_data)
        self.n_workers = leaves[0].shape[0]
        self.n_local = leaves[0].shape[1]
        if deterministic:
            batch = self.n_local
        assert batch is not None, "batch is required for stochastic mode"
        if per_device is not None:
            assert batch % per_device == 0, (batch, per_device)
            accum = batch // per_device
        assert batch % accum == 0, (batch, accum)
        self.batch = batch
        self.accum = accum
        self.micro = batch // accum
        self.deterministic = deterministic
        self.stochastic = not deterministic
        self.scale = (self.n_local / batch) if scale is None else scale
        self._key0 = jax.random.PRNGKey(seed)
        self._worker_ids = jnp.arange(self.n_workers)

    def stream_keys(self, stream: int, step):
        ks = jax.random.fold_in(jax.random.fold_in(self._key0, stream), step)
        return jax.vmap(lambda m: jax.random.fold_in(ks, m))(self._worker_ids)

    def sample(self, step):
        """[W, accum, micro, ...] microbatches.  Stochastic mode draws the
        SAME ``(batch,)`` index vector as ``MinibatchSource`` and reshapes
        it into microbatches; deterministic mode chunks the whole corpus."""
        if self.deterministic:
            return jax.tree.map(
                lambda x: x.reshape((x.shape[0], self.accum, self.micro)
                                    + x.shape[2:]), self.worker_data)

        def sample1(data_m, key):
            idx = jax.random.randint(key, (self.batch,), 0, self.n_local)
            idx = idx.reshape(self.accum, self.micro)
            return jax.tree.map(lambda x: x[idx], data_m)

        return jax.vmap(sample1)(self.worker_data, self.stream_keys(0, step))

    def eval_at(self, params, thetas_w, batches):
        """This round's accumulated gradients at per-worker iterates, f32
        and ``scale``-multiplied — same evaluation-point contract as
        ``MinibatchSource.eval_at`` (WK2 stale iterates, SVRG anchors and
        delay-mode params all route through here with identical
        microbatching)."""
        if thetas_w is None:
            thetas_w = broadcast_w(params, self.n_workers)

        if self.accum == 1:
            # one microbatch: evaluate directly, exactly like
            # MinibatchSource (and like the sharded step's microbatch==1
            # special case) — the scan wrapper would perturb XLA's fusion
            # and cost the bit-identity contract a ulp
            return jax.vmap(lambda t, b: jax.tree.map(
                lambda g: g.astype(jnp.float32) * self.scale,
                jax.grad(self.loss_fn)(
                    t, jax.tree.map(lambda x: jnp.squeeze(x, 0), b))))(
                thetas_w, batches)

        def one(t, mbs):
            _, g = accumulate_loss_grads(self.loss_fn, t, mbs)
            return jax.tree.map(lambda x: x * self.scale, g)

        return jax.vmap(one)(thetas_w, batches)

    def _chunk_full(self, data_m):
        c = self.micro if self.n_local % self.micro == 0 else self.n_local
        return jax.tree.map(
            lambda x: x.reshape((self.n_local // c, c) + x.shape[1:]), data_m)

    def full_local_grads(self, params):
        """Exact per-worker full local gradients (the SVRG anchor's mu),
        accumulated over corpus chunks at the configured microbatch size —
        mean-convention ``loss_fn`` means no extra scale, exactly like
        ``MinibatchSource.full_local_grads``."""
        def one(data_m):
            _, g = accumulate_loss_grads(self.loss_fn, params,
                                         self._chunk_full(data_m))
            return g

        return jax.vmap(one)(self.worker_data)

    def global_loss(self, params):
        def worker_loss(data_m):
            mbs = self._chunk_full(data_m)
            n = jax.tree_util.tree_leaves(mbs)[0].shape[0]

            def body(acc, b):
                return acc + self.loss_fn(params, b) / n, None

            return jax.lax.scan(body, jnp.zeros((), jnp.float32), mbs)[0]

        return jnp.sum(jax.vmap(worker_loss)(self.worker_data))

    def grad_norm_sq(self, params, grads):
        if self.deterministic:
            # the summed full-corpus gradients ARE the global gradient
            # (FullBatchSource's reduction-not-backprop record)
            return tree_sq_norm(jax.tree.map(lambda g: jnp.sum(g, axis=0),
                                             grads))
        return tree_sq_norm(jax.grad(self.global_loss)(params))


# ---------------------------------------------------------------------------
# Shared round stages: SVRG correction and the WK2 stale side.  These are
# the blocks that used to be copy-pasted between run_gradient_based,
# run_stochastic and launch/train.py — they live here once now.
# ---------------------------------------------------------------------------

def apply_svrg_exact(sv: SvrgState, params, grads, grad_at, full_local_grads,
                     step, cfg: StrategyConfig, n_workers: int):
    """SVRG correction with an exact periodic anchor (simulated runners).

    Every ``cfg.svrg_period`` rounds the anchor snaps to the current
    iterate and ``mu`` to the exact full *local* gradient there (inside a
    ``lax.cond`` — the refresh backprop only runs on refresh rounds);
    between refreshes the correction ``mu - g(theta_anchor; xi)`` is added
    to the minibatch gradient.  ``grad_at(thetas_w)`` must evaluate the
    CURRENT sample at arbitrary per-worker iterates (the engine closes it
    over this round's batches) so the same ``corr`` can hit the WK2 stale
    side and anchors cancel in the same-sample difference.

    Returns ``(grads_corrected, corr, sv_new)``.
    """

    def refresh(s):
        mu = full_local_grads(params)
        return SvrgState(
            theta_anchor=broadcast_w(params, n_workers),
            mu_anchor=jax.tree.map(lambda g: g.astype(jnp.float32), mu))

    sv = jax.lax.cond(step % cfg.svrg_period == 0, refresh, lambda s: s, sv)
    g_anchor = grad_at(sv.theta_anchor)
    corr = jax.tree.map(lambda mu, ga: mu - ga, sv.mu_anchor, g_anchor)
    grads = jax.tree.map(lambda g, c: g + c, grads, corr)
    return grads, corr, sv


def apply_svrg_streaming(sv: SvrgState, params, grads, grad_at, step,
                         cfg: StrategyConfig):
    """SVRG correction with a *streaming* one-batch anchor (sharded launch
    path).  The launch path streams data, so the exact full-local-gradient
    anchor is approximated by the current *batch* gradient at refresh time
    (anchor noise frozen for the period rather than eliminated — a
    documented degradation); the refresh is a traced where-select so the
    step stays a single trace, and the anchor backprop runs every step
    (SVRG's inherent 2x compute).  No leading worker dim: one shard's
    slice, like ``qhat`` in the sharded step.

    Returns ``(grads_corrected, corr, sv_new)``.
    """
    refresh = (step % cfg.svrg_period == 0).astype(jnp.float32)
    theta_anchor = jax.tree.map(
        lambda p_, t: refresh * p_.astype(jnp.float32) + (1.0 - refresh) * t,
        params, sv.theta_anchor)
    mu = jax.tree.map(
        lambda g, m: refresh * g.astype(jnp.float32) + (1.0 - refresh) * m,
        grads, sv.mu_anchor)
    g_anchor = grad_at(theta_anchor)
    corr = jax.tree.map(lambda m, ga: m - ga.astype(jnp.float32), mu, g_anchor)
    grads = jax.tree.map(lambda g, c: g.astype(jnp.float32) + c, grads, corr)
    return grads, corr, SvrgState(theta_anchor, mu)


def stale_side_grads(grad_at, theta_last, corr):
    """The WK2 second backprop: the CURRENT sample re-evaluated at the
    stale iterate(s) ``theta_last``, with the SVRG correction (if any)
    applied to this side too so anchor and mu cancel in the same-sample
    difference.  ``grad_at`` is the same evaluator the primal gradients
    used (same microbatching / scaling), closed over this round's batch.
    """
    gs = grad_at(theta_last)
    if corr is not None:
        gs = jax.tree.map(lambda g, c: g.astype(jnp.float32) + c, gs, corr)
    return gs


# ---------------------------------------------------------------------------
# Participation models.
# ---------------------------------------------------------------------------

def participation_mask(cfg: StrategyConfig, step, n_workers: int):
    """[W] bool availability mask for round ``step`` — or ``None`` for the
    modes that never mask (``full``, ``delay``).

    Deterministic in ``(participation_seed, step)`` and independent of the
    batch/compressor streams, so the SAME cohort is drawn by the simulated
    engine and by every shard of the sharded step (each indexes its own
    slot).  ``bernoulli`` keeps each worker independently with probability
    ``participation_p``; ``fixed_k`` keeps exactly
    ``max(1, round(p * W))`` workers drawn uniformly (the k lowest of W
    iid uniform scores — ties have measure zero).
    """
    if cfg.participation in ("full", "delay"):
        return None
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.participation_seed), step)
    if cfg.participation == "bernoulli":
        return jax.random.bernoulli(key, cfg.participation_p, (n_workers,))
    if cfg.participation == "fixed_k":
        k = max(1, int(round(cfg.participation_p * n_workers)))
        scores = jax.random.uniform(key, (n_workers,))
        return scores <= jnp.sort(scores)[k - 1]
    if cfg.participation == "markov":
        raise ValueError(
            "markov churn is stateful (the chain carries the on/off state "
            "between rounds) — it has no stateless mask; use "
            "MarkovParticipation via make_participation (simulated engine "
            "only)")
    raise ValueError(f"unknown participation {cfg.participation!r}; "
                     f"have {PARTICIPATION}")


class FullParticipation:
    """Every worker reachable every round (the paper's setting)."""

    def init(self, params0):
        return None

    def begin_round(self, pstate, step, params):
        """Returns ``(avail, thetas_w, pstate)`` — ``avail`` the [W] bool
        mask (None = all available), ``thetas_w`` per-worker evaluation
        iterates (None = the current replicated params)."""
        return None, None, pstate


class SampledParticipation:
    """Bernoulli / fixed-k client sampling (see :func:`participation_mask`)."""

    def __init__(self, cfg: StrategyConfig, n_workers: int):
        assert 0.0 < cfg.participation_p <= 1.0, cfg.participation_p
        self.cfg = cfg
        self.n_workers = n_workers

    def init(self, params0):
        return None

    def begin_round(self, pstate, step, params):
        return (participation_mask(self.cfg, step, self.n_workers),
                None, pstate)


class MarkovParticipation:
    """Bursty on/off availability: a per-worker two-state Markov chain.

    The carried ROADMAP item: real fleets churn in *bursts* (a worker that
    just dropped tends to stay dropped), which i.i.d. bernoulli sampling
    cannot express.  Each worker holds a bool on/off state; at round start
    it transitions with ``P(on -> off) = 1 / sojourn`` and ``P(off -> on) =
    p_down * p / (1 - p)``, giving stationary availability exactly
    ``participation_p`` and a mean ON-streak of ``markov_sojourn`` rounds
    — so churn burstiness is dialed at *matched mean availability*
    (``benchmarks/participation_frontier.py`` measures the cost of the
    bursts).  ``sojourn = 1 / (1 - p)`` makes both transition
    probabilities equal ``1 - p`` / ``p``-complementary, i.e. the next
    state is independent of the current one: the chain degenerates to
    i.i.d. bernoulli(p), subsuming ``participation="bernoulli"`` as a
    special case (distributionally — the draws come from a different
    stream).  The initial state is drawn from the stationary law on its
    own fold_in stream.  Simulated engine only: the carried chain state is
    exactly what :func:`participation_mask`'s stateless contract (and with
    it the sharded step) cannot express.
    """

    def __init__(self, cfg: StrategyConfig, n_workers: int):
        p = cfg.participation_p
        assert 0.0 < p < 1.0, p
        assert cfg.markov_sojourn >= 1.0, cfg.markov_sojourn
        self.p = p
        self.p_down = min(1.0, 1.0 / cfg.markov_sojourn)
        self.p_up = min(1.0, self.p_down * p / (1.0 - p))
        self.n_workers = n_workers
        self._key0 = jax.random.PRNGKey(cfg.participation_seed)

    def init(self, params0):
        # stationary initial state; stream 1 (transitions draw on stream 0)
        return jax.random.bernoulli(jax.random.fold_in(self._key0, 1),
                                    self.p, (self.n_workers,))

    def begin_round(self, on, step, params):
        u = jax.random.uniform(
            jax.random.fold_in(jax.random.fold_in(self._key0, 0), step),
            (self.n_workers,))
        on = jnp.where(on, u >= self.p_down, u < self.p_up)
        return on, None, on


class DelayedParticipation:
    """Bounded-delay asynchronous workers (heterogeneous per-worker cost).

    Worker ``m`` has the fixed staleness ``d_m = m mod (max_delay + 1)``
    and computes this round's gradient at ``theta^{k - d_m}`` — the server
    applies it at round ``k`` (the classic bounded-staleness async model;
    delays are spread across the grid so every run exercises every
    staleness level).  State is a replicated params history ring of
    ``max_delay + 1`` iterates, pushed at round start; all workers stay
    *reachable* (``avail=None``) — staleness, not absence.
    """

    def __init__(self, max_delay: int, n_workers: int):
        assert max_delay >= 1, "use participation='full' for max_delay=0"
        self.length = max_delay + 1
        self.delays = jnp.arange(n_workers) % self.length

    def init(self, params0):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (self.length,) + l.shape),
            params0)

    def begin_round(self, hist, step, params):
        # hist[d] = theta^{k-d} after the push (index 0 = current round)
        hist = jax.tree.map(
            lambda h, p_: jnp.concatenate([p_[None].astype(h.dtype), h[:-1]],
                                          axis=0), hist, params)
        thetas = jax.tree.map(lambda h: h[self.delays], hist)
        return None, thetas, hist


def make_participation(cfg: StrategyConfig, n_workers: int):
    """Participation model for ``cfg`` (normalizing the degenerate knobs:
    ``delay`` with ``max_delay=0`` and sampling with ``p >= 1`` are exactly
    full participation and route to it, keeping trajectories bitwise equal
    to the pre-participation code)."""
    assert cfg.participation in PARTICIPATION, cfg.participation
    if cfg.participation == "delay":
        assert cfg.max_delay >= 0, cfg.max_delay
        if cfg.max_delay == 0:
            return FullParticipation()
        return DelayedParticipation(cfg.max_delay, n_workers)
    if cfg.participation in ("bernoulli", "fixed_k"):
        if cfg.participation_p >= 1.0 and cfg.participation != "fixed_k":
            return FullParticipation()
        if cfg.participation == "fixed_k" and \
                max(1, int(round(cfg.participation_p * n_workers))) == n_workers:
            return FullParticipation()
        return SampledParticipation(cfg, n_workers)
    if cfg.participation == "markov":
        if cfg.participation_p >= 1.0:
            return FullParticipation()
        return MarkovParticipation(cfg, n_workers)
    return FullParticipation()


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

class RoundEngine:
    """One LAQ communication round as a scan body, stages plugged in.

    ``baseline`` selects the dense-baseline path instead of the LAQ state
    machine: ``None`` runs worker_update/aggregate under ``cfg``; one of
    ``("sgd", "qsgd", "ssgd")`` runs the matching compressor with ``bits``
    / ``density`` (CommState is then bookkeeping only).  ``track_history``
    controls the criterion's ``theta_hist`` push (the stochastic wrapper
    historically pushes only for the LAQ family).
    """

    def __init__(self, source, cfg: StrategyConfig, *, alpha,
                 baseline: Optional[str] = None, bits: int = 3,
                 density: float = 0.1, track_history: bool = True,
                 participation=None):
        assert baseline in (None, "sgd", "qsgd", "ssgd"), baseline
        if baseline is not None and not source.stochastic:
            raise ValueError("dense baselines need a stochastic source "
                             "(their compressor keys come from its stream 1)")
        if baseline is not None and cfg.faults.active:
            raise ValueError("fault injection targets the LAQ state machine "
                             "(qhat / clocks / estimator state); the dense "
                             "baselines carry none of it — run them with "
                             "faults off")
        self.source = source
        self.cfg = cfg
        self.alpha = alpha
        self.baseline = baseline
        self.bits = bits
        self.density = density
        self.track_history = track_history
        self.n_workers = source.n_workers
        self.participation = (participation if participation is not None
                              else make_participation(cfg, self.n_workers))
        self.wk2 = (baseline is None and cfg.lazy
                    and cfg.lazy_rule == "lasg_wk2")

    def init_carry(self, params0):
        return (params0, init_comm_state(params0, self.n_workers, self.cfg),
                self.participation.init(params0))

    def round(self, carry, _):
        """Scan body: one communication round.  Returns the new carry and
        the per-round record ``(loss, grad_norm_sq, total_uploads,
        total_bits, quant_err, mean_bits)``."""
        cfg, source = self.cfg, self.source
        params, cst, pstate = carry
        alpha_k = eta_at(cfg.eta_schedule, self.alpha, cst.step)

        avail, thetas_w, pstate = self.participation.begin_round(
            pstate, cst.step, params)
        batches = source.sample(cst.step)
        grads = source.eval_at(params, thetas_w, batches)

        flt = cfg.faults
        if flt.crashy:
            # crash-restart BEFORE the svrg/wk2 stages: the restarted
            # worker's fresh anchors are what this round computes against.
            # mu restarts from this round's (pre-correction) gradient — the
            # streaming-style refresh (core/faults.py).
            cst = apply_crashes(
                cst, crash_mask(flt, cst.step, self.n_workers), params,
                grads, cfg, reconcile=cfg.defense.reconcile_crashes)

        corr = None
        if source.stochastic and cfg.variance_reduced:
            grads, corr, svrg = apply_svrg_exact(
                cst.svrg, params, grads,
                lambda th: source.eval_at(params, th, batches),
                source.full_local_grads, cst.step, cfg, self.n_workers)
            cst = cst._replace(svrg=svrg)

        if self.baseline is None:
            grads_stale = None
            if self.wk2:
                grads_stale = stale_side_grads(
                    lambda th: source.eval_at(params, th, batches),
                    cst.lazy.theta_last, corr)
            # payload corruption AFTER the svrg/wk2 stages: the fault hits
            # the outgoing payload (what the worker ships), not the local
            # computation — the stale side stays honest, so the wk2 rule
            # sees a huge same-sample difference and uploads the garbage,
            # exactly the failure mode a corrupt sender produces
            grads_out = grads
            fault_flip = fault_keys = None
            if flt.grad_faulty:
                grads_out = corrupt_grads(
                    grads, corruption_mask(flt, cst.step, self.n_workers),
                    flt)
            elif flt.wire_faulty:
                fault_flip = corruption_mask(flt, cst.step, self.n_workers)
                fault_keys = bitflip_keys(flt, cst.step, self.n_workers)
            agg, cst, metrics = aggregate(cst, grads_out, alpha_k, cfg,
                                          params=params,
                                          grads_stale=grads_stale,
                                          avail=avail,
                                          fault_flip=fault_flip,
                                          fault_keys=fault_keys)
            qe, mb = metrics.radius_max, metrics.mean_bits
        else:
            agg, cst, qe, mb = self._baseline_round(cst, grads, avail)

        new_params = jax.tree.map(lambda t, g: t - alpha_k * g, params, agg)
        if self.track_history:
            dsq = tree_sq_norm(jax.tree.map(lambda a, b: a - b,
                                            new_params, params))
            cst = finalize_step(cst, dsq)
        rec = (source.global_loss(params), source.grad_norm_sq(params, grads),
               cst.total_uploads, cst.total_bits, qe, mb)
        return (new_params, cst, pstate), rec

    def _baseline_round(self, cst: CommState, grads, avail):
        """Dense-baseline aggregation: every available worker uploads its
        (compressed) gradient; no server recursion, no skip state."""
        kind = self.baseline
        W = self.n_workers
        p = tree_size(grads) // W
        keys_cmp = self.source.stream_keys(1, cst.step)
        if kind == "sgd":
            cgrads = grads
            bits_m = jnp.full((W,), float(dense_bits(p)))
        elif kind == "qsgd":
            cgrads, bits_m = jax.vmap(
                lambda k, g: qsgd_compress(k, g, self.bits))(keys_cmp, grads)
        else:
            cgrads, bits_m = jax.vmap(
                lambda k, g: ssgd_compress(k, g, self.density))(keys_cmp,
                                                                grads)
        if avail is None:
            n_up = W
            mb = jnp.mean(bits_m) / p
        else:
            keep = avail.astype(jnp.float32)
            cgrads = jax.tree.map(
                lambda g: g * keep.reshape((-1,) + (1,) * (g.ndim - 1)),
                cgrads)
            bits_m = bits_m * keep
            n_up = jnp.sum(avail.astype(jnp.int32))
            mb = jnp.sum(bits_m) / jnp.maximum(jnp.sum(keep), 1.0) / p
        agg = jax.tree.map(lambda g: jnp.sum(g, axis=0), cgrads)
        cst = cst._replace(total_bits=cst.total_bits + jnp.sum(bits_m),
                           total_uploads=cst.total_uploads + n_up,
                           step=cst.step + 1)
        return agg, cst, jnp.zeros(()), mb

    def run_from(self, carry, steps: int):
        """Scan ``steps`` rounds from an arbitrary carry — the resume entry
        point (checkpoint restart, the divergence watchdog's chunked
        supervision in core/defense.py).  Returns ``(carry, RunResult)``;
        ``run`` is ``run_from(init_carry(params0))``, so a run split across
        ``run_from`` calls is bitwise identical to one uninterrupted scan
        (tests/test_checkpoint.py pins this through a save/load cycle).
        """
        carry, recs = jax.lax.scan(self.round, carry, None, length=steps)
        loss, gn, cu, cb, qe, mb = recs
        return carry, RunResult(carry[0], loss, gn, cu, cb, qe, mb)

    def run(self, params0, steps: int) -> RunResult:
        _, result = self.run_from(self.init_carry(params0), steps)
        return result
