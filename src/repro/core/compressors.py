"""Unbiased-compression baselines used in the paper's Table 3.

* QSGD (Alistarh et al., 2017, paper ref [2]): random b-bit quantization
  q(v)_i = ||v||_2 * sign(v_i) * xi_i(v, s),  s = 2^b - 1 levels, unbiased.
* SSGD (Wangni et al., 2018, paper ref [30]): unbiased magnitude-proportional
  random sparsification: coordinate i kept with prob p_i ~ |v_i|, rescaled by
  1/p_i; expected density is ``density``.

Both are applied per-worker on the stochastic gradient and upload every
round by construction — they are the *dense-communication* baselines.  The
lazy stochastic methods (SLAQ with the eq.-7a, LASG-WK or LASG-PS skip rule;
see :mod:`repro.core.lazy_rules` and ``StrategyConfig.lazy_rule``) are the
counterpoint: quantized innovations plus skipped rounds.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Pytree = object


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    return flat, (treedef, shapes, sizes)


def _unflat(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for sh, sz in zip(shapes, sizes):
        out.append(flat[off:off + sz].reshape(sh))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def qsgd_compress(key, grad: Pytree, bits: int):
    """Returns (compressed_grad, wire_bits). Unbiased: E[out] = grad."""
    v, meta = _flat(grad)
    s = 2.0**bits - 1.0
    norm = jnp.linalg.norm(v)
    scaled = jnp.where(norm > 0, jnp.abs(v) / norm * s, jnp.zeros_like(v))
    lo = jnp.floor(scaled)
    prob = scaled - lo
    rnd = jax.random.uniform(key, v.shape)
    level = lo + (rnd < prob).astype(jnp.float32)
    out = jnp.sign(v) * level * norm / s
    # wire: 32 bits for the norm + (b + 1 sign) bits per coordinate
    wire_bits = 32.0 + (bits + 1) * v.size
    return _unflat(out, meta), jnp.asarray(wire_bits, jnp.float32)


def ssgd_compress(key, grad: Pytree, density: float):
    """Unbiased random sparsification with expected density ``density``."""
    v, meta = _flat(grad)
    p = v.size
    absv = jnp.abs(v)
    denom = jnp.sum(absv)
    # one-shot probabilities, clipped to [_, 1]; rescale keeps E close to k.
    k = density * p
    probs = jnp.where(denom > 0, jnp.minimum(1.0, k * absv / denom), jnp.zeros_like(v))
    keep = jax.random.uniform(key, v.shape) < probs
    out = jnp.where(keep, v / jnp.maximum(probs, 1e-12), 0.0)
    nnz = jnp.sum(keep.astype(jnp.float32))
    # wire: 32-bit value + index (ceil(log2 p) bits) per surviving coordinate
    idx_bits = max(1, int(math.ceil(math.log2(p))))
    wire_bits = nnz * (32.0 + idx_bits)
    return _unflat(out, meta), wire_bits
