"""Composable compressor pipeline (EF-LAQ) + unbiased dense baselines.

The LAQ quantizer (paper eq. 5-6) compresses the gradient *innovation*
``g - qhat`` with a fixed b-bit uniform grid.  This module generalizes that
single stage into a pipeline of :class:`Compressor` stages —

    sparsify (top-k / rand-k)  ->  quantize (b-bit grid)  ->  pack (bytes)

— selected via ``StrategyConfig.compressor``, plus the **error-feedback**
memory that makes the aggressive regimes work: the pre-compression residual
``e_m = g_eff - Q(g_eff)`` is carried in ``CommState.error`` (an
:class:`ErrorState`, ``None``-gated exactly like ``LazyState`` /
``SvrgState``) and added back before the next compress,

    g_eff^k = g_m^k + e_m^{k-1},        e_m^k = g_eff^k - q_new^k,

committed only on upload (frozen over lazy skips / unavailable rounds, like
``qhat``).  Error compensation provably recovers convergence for biased
contractive compressors (Deng et al., arXiv:2112.04088) — the regime the
``benchmarks/ef_frontier.py`` headline measures at b in {1, 2}.

Stage contract (documented normatively in ``docs/compressors.md``):

* ``init_state(template, n_workers)`` — per-worker carried state, or
  ``None`` for stateless stages (all the wire stages are stateless; the
  error memory is pipeline-level state, owned by ``CommState.error``);
* ``compress(x, ctx)``  — forward one stage; reads/writes the shared
  ``ctx`` dict (keys: ``p``, ``idx``, ``R``, ``key``);
* ``decompress(y, ctx)`` — exact inverse of the *representation* (the
  value loss happened in ``compress``).

The pipeline runs under ``vmap``/``scan``/``jit``: ``k`` is static, all
shapes fixed.  The quantize stage's elementwise math is routed through the
wire backend (``core/wire.py``) so the reference and fused lowerings stay
bit-identical; :func:`repro.core.wire.sparse_roundtrip` is the integration
point ``worker_update`` uses.

The unbiased dense baselines (QSGD, paper ref [2]; SSGD, paper ref [30])
remain at the bottom — they upload every round by construction and are the
Table-3 counterpoint to the lazy pipeline.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .quantize import pack_codes, unpack_codes

Pytree = object

COMPRESSORS = ("none", "topk", "randk")


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves]
        or [jnp.zeros((0,), jnp.float32)])
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    return flat, (treedef, shapes, sizes)


def _unflat(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for sh, sz in zip(shapes, sizes):
        out.append(flat[off:off + sz].reshape(sh))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def static_k(k_frac: float, p: int) -> int:
    """Static survivor count for a keep-fraction: ``round(k_frac * p)``
    clipped to [0, p].  Static so the sparse payload has a fixed shape
    under jit (k=0 and k=p are legal degenerate pipelines — tested)."""
    assert 0.0 <= k_frac <= 1.0, k_frac
    return min(p, max(0, int(round(k_frac * p))))


def compressor_keys(seed: int, step, n_workers: int):
    """[W] per-worker rand-k selection keys for round ``step``.

    Functionally derived from ``(seed, step, worker)`` by ``fold_in`` — no
    carried split chain — so the simulated engine and every shard of the
    sharded step draw the SAME support (each indexes its own slot), and the
    stream is independent of the batch / participation RNG.
    """
    ks = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.vmap(lambda m: jax.random.fold_in(ks, m))(
        jnp.arange(n_workers))


# ---------------------------------------------------------------------------
# Stage implementations.
# ---------------------------------------------------------------------------

class Compressor:
    """One pipeline stage: ``init_state`` / ``compress`` / ``decompress``."""

    name = "?"

    def init_state(self, template: Pytree, n_workers: int):
        """Per-worker carried state ([W, ...] leaves) or None (stateless)."""
        return None

    def compress(self, x, ctx: dict):
        raise NotImplementedError

    def decompress(self, y, ctx: dict):
        raise NotImplementedError


class SparseSelection(NamedTuple):
    """A sparsifier's output: ``idx`` sorted ascending (the canonical wire
    order — both backends emit identical index payloads), ``vals`` the
    surviving coordinates in that order."""
    idx: jax.Array          # int32 [k]
    vals: jax.Array         # f32 [k]


def select_support(mode: str, flat: jax.Array, k: int, key=None):
    """Support selection shared by both sparsifier stages and both wire
    backends: ``topk`` keeps the k largest-|.| coordinates, ``randk`` keeps
    k uniform-without-replacement coordinates (the k largest of p iid
    uniform scores — ties have measure zero).  Indices are sorted ascending
    so the wire payload is canonical regardless of top_k's internal order.
    """
    p = flat.shape[0]
    if k <= 0:
        return SparseSelection(jnp.zeros((0,), jnp.int32),
                               jnp.zeros((0,), jnp.float32))
    if k >= p:
        idx = jnp.arange(p, dtype=jnp.int32)
        return SparseSelection(idx, flat)
    if mode == "topk":
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
    elif mode == "randk":
        assert key is not None, "randk needs a selection key"
        _, idx = jax.lax.top_k(jax.random.uniform(key, (p,)), k)
    else:
        raise ValueError(f"unknown sparsifier {mode!r}; "
                         f"have {COMPRESSORS[1:]}")
    idx = jnp.sort(idx).astype(jnp.int32)
    return SparseSelection(idx, flat[idx])


def scatter_selection(sel: SparseSelection, vals, p: int):
    """Dense flat vector with ``vals`` at ``sel.idx`` and zeros elsewhere
    (the receiver's view of a sparse payload)."""
    return jnp.zeros((p,), jnp.float32).at[sel.idx].set(vals)


class TopKSparsifier(Compressor):
    """Keep the k largest-magnitude coordinates (biased, contractive)."""

    name = "topk"

    def __init__(self, k: int):
        self.k = int(k)

    def compress(self, flat, ctx):
        ctx["p"] = flat.shape[0]
        sel = select_support(self.name, flat, self.k, ctx.get("key"))
        ctx["idx"] = sel.idx
        return sel

    def decompress(self, sel: SparseSelection, ctx):
        return scatter_selection(sel, sel.vals, ctx["p"])


class RandKSparsifier(TopKSparsifier):
    """Keep k uniformly random coordinates.  Values ship *unscaled* (the
    1/prob rescale of unbiased rand-k would blow up the variance at small
    k); the bias is exactly what the error memory compensates."""

    name = "randk"


class UniformQuantizer(Compressor):
    """Sign-magnitude b-bit grid on the surviving values: one sign bit plus
    ``b - 1`` magnitude bits uniform on ``[lo, hi] = [min |v|, max |v|]``
    (b = 1 collapses to ``lo = hi = mean |v|`` — the L2-optimal scaled-sign
    code).  NOT the dense wire's zero-less eq. 5-6 grid: that grid's
    smallest representable magnitude is ``R/(2^b - 1)`` AWAY from small
    survivors, so it injects O(R) error on them and the compressor stops
    being contractive — exactly the property error feedback needs to
    converge (the EF recursion amplifies non-contracted error; see
    docs/compressors.md).  Sign-magnitude on the survivor range is
    contractive by construction: ``sum (|v| - mean|v|)^2 < sum v^2`` at
    b = 1, and per-coordinate error <= step/2 on [lo, hi] above.

    The elementwise map is pluggable so the fused wire backend can
    substitute its kernel lowering (``quantize_fn(vals, lo, hi, bits) ->
    (codes, deq)``); the default is the reference jnp path.
    """

    name = "quantize"

    def __init__(self, bits: int, quantize_fn=None):
        self.bits = int(bits)
        self.quantize_fn = quantize_fn or reference_sparse_quantize

    def compress(self, sel: SparseSelection, ctx):
        lo, hi = sparse_grid(sel.vals, self.bits)
        codes, deq = self.quantize_fn(sel.vals, lo, hi, self.bits)
        ctx["lo"], ctx["hi"] = lo, hi
        ctx["deq"] = deq
        return SparseSelection(sel.idx, codes)

    def decompress(self, coded: SparseSelection, ctx):
        d = sparse_dequantize(coded.vals, ctx["lo"], ctx["hi"], self.bits)
        return SparseSelection(coded.idx, d)


class CodePacker(Compressor):
    """Physical byte layout: codes packed 8/b per byte (midpoint-padded to
    whole bytes, like the dense wire), indices as int32 — the accounting
    charges ``ceil(log2 p)`` bits each (``quantize.sparse_upload_bits``);
    the normative layout is ``docs/compressors.md``."""

    name = "pack"

    def __init__(self, bits: int):
        self.bits = int(bits)

    def compress(self, coded: SparseSelection, ctx):
        cpb = 8 // self.bits
        mid = jnp.uint8((2 ** self.bits) // 2)
        flat = coded.vals.astype(jnp.uint8)
        pad = (-flat.shape[0]) % cpb
        if pad:
            flat = jnp.concatenate([flat, jnp.full((pad,), mid, jnp.uint8)])
        return coded.idx, pack_codes(flat, self.bits)

    def decompress(self, payload, ctx):
        idx, packed = payload
        codes = unpack_codes(packed, self.bits)[:idx.shape[0]]
        return SparseSelection(idx, codes)


class CompressorPipeline:
    """Compose stages: ``compress`` runs them forward (returning the final
    wire object plus the shared ctx), ``decompress`` runs the inverses in
    reverse.  ``roundtrip`` is the worker-side form: what the receiver
    reconstructs, with every intermediate exposed."""

    def __init__(self, stages):
        self.stages = list(stages)

    def init_state(self, template, n_workers):
        return [s.init_state(template, n_workers) for s in self.stages]

    def compress(self, x, ctx: Optional[dict] = None, key=None):
        ctx = {} if ctx is None else ctx
        if key is not None:
            ctx["key"] = key
        for s in self.stages:
            x = s.compress(x, ctx)
        return x, ctx

    def decompress(self, y, ctx: dict):
        for s in reversed(self.stages):
            y = s.decompress(y, ctx)
        return y

    def roundtrip(self, flat, key=None):
        """(dense_reconstruction, wire, ctx) for a flat f32 vector."""
        wire, ctx = self.compress(flat, key=key)
        return self.decompress(wire, ctx), wire, ctx


def make_compressor(mode: str, k: int, bits: int,
                    quantize_fn=None) -> CompressorPipeline:
    """The standard EF-LAQ pipeline for ``StrategyConfig.compressor``:
    sparsify -> quantize -> pack.  ``k`` is the static survivor count
    (:func:`static_k`); ``quantize_fn`` lets a wire backend substitute its
    lowering of the grid math."""
    assert mode in COMPRESSORS[1:], mode
    sparsifier = (TopKSparsifier if mode == "topk" else RandKSparsifier)(k)
    return CompressorPipeline([sparsifier,
                               UniformQuantizer(bits, quantize_fn),
                               CodePacker(bits)])


def sparse_grid(vals, bits: int):
    """(lo, hi) endpoints of the sign-magnitude grid (f32 scalars, the two
    wire sidecars).  Shared by both wire backends so the sidecar bytes are
    identical by construction; only the elementwise code map below has a
    kernel lowering."""
    if vals.size == 0:          # k is static, so this is a trace-time branch
        z = jnp.zeros((), jnp.float32)
        return z, z
    a = jnp.abs(vals.astype(jnp.float32))
    if bits == 1:
        mu = jnp.mean(a)
        return mu, mu
    return jnp.min(a), jnp.max(a)


def reference_sparse_quantize(vals, lo, hi, bits: int):
    """Reference lowering of the quantize-stage code map: ``(codes, deq)``
    with ``codes = (sign << (b-1)) | mag`` and ``mag`` the nearest of the
    ``2^(b-1)`` uniform levels on [lo, hi] — the fused backend's kernel
    must match it bitwise (tests/test_wire_backend.py)."""
    L = 2 ** (bits - 1) - 1              # magnitude levels above lo
    a = jnp.abs(vals.astype(jnp.float32))
    neg = vals < 0
    step = (hi - lo) / max(L, 1)
    safe = jnp.where(step > 0, step, 1.0)
    mag = jnp.clip(jnp.floor((a - lo) / safe + 0.5), 0, L)
    mag = jnp.where(step > 0, mag, jnp.zeros_like(mag)).astype(jnp.uint8)
    codes = ((neg.astype(jnp.uint8) << (bits - 1)) | mag).astype(jnp.uint8)
    deq = jnp.where(neg, -1.0, 1.0) * (lo + mag.astype(jnp.float32) * step)
    return codes, deq


def sparse_dequantize(codes, lo, hi, bits: int):
    """Receiver-side inverse of the code map (codes uint8 -> f32 values)."""
    L = 2 ** (bits - 1) - 1
    mag = (codes & L).astype(jnp.float32)
    neg = (codes >> (bits - 1)).astype(jnp.float32)
    step = (hi - lo) / max(L, 1)
    return (1.0 - 2.0 * neg) * (lo + mag * step)


# ---------------------------------------------------------------------------
# Error-feedback memory (EF-LAQ).
# ---------------------------------------------------------------------------

class ErrorState(NamedTuple):
    """Per-worker error-feedback residual ``e_m`` (``None`` unless
    ``StrategyConfig.error_feedback`` — the pytree discipline of
    ``LazyState`` / ``SvrgState``: the field simply vanishes from the
    flattened state when the mode is off, so goldens and sharded exchanges
    are untouched).  Leading worker dim in simulated mode, one slice per
    shard in sharded mode — exactly like ``qhat``."""
    residual: Optional[Pytree]


def empty_error_state() -> ErrorState:
    return ErrorState(None)


def init_error_state(error_feedback: bool, grad_template: Pytree,
                     n_workers: int, *, worker_dim: bool = True) -> ErrorState:
    """Zero residual per worker (round 0 has no compression error yet)."""
    if not error_feedback:
        return ErrorState(None)
    wshape = (n_workers,) if worker_dim else ()
    return ErrorState(residual=jax.tree.map(
        lambda l: jnp.zeros(wshape + l.shape, jnp.float32), grad_template))


# ---------------------------------------------------------------------------
# Unbiased dense baselines (paper Table 3).
# ---------------------------------------------------------------------------

def qsgd_compress(key, grad: Pytree, bits: int):
    """QSGD (Alistarh et al., 2017, paper ref [2]): random b-bit
    quantization, unbiased: E[out] = grad.  Returns
    ``(compressed_grad, wire_bits)``."""
    v, meta = _flat(grad)
    s = 2.0**bits - 1.0
    norm = jnp.linalg.norm(v)
    scaled = jnp.where(norm > 0, jnp.abs(v) / norm * s, jnp.zeros_like(v))
    lo = jnp.floor(scaled)
    prob = scaled - lo
    rnd = jax.random.uniform(key, v.shape)
    level = lo + (rnd < prob).astype(jnp.float32)
    out = jnp.sign(v) * level * norm / s
    # wire: 32 bits for the norm + (b + 1 sign) bits per coordinate
    wire_bits = 32.0 + (bits + 1) * v.size
    return _unflat(out, meta), jnp.asarray(wire_bits, jnp.float32)


def ssgd_compress(key, grad: Pytree, density: float):
    """SSGD (Wangni et al., 2018, paper ref [30]): unbiased magnitude-
    proportional random sparsification with expected density ``density``."""
    v, meta = _flat(grad)
    p = v.size
    absv = jnp.abs(v)
    denom = jnp.sum(absv)
    # one-shot probabilities, clipped to [_, 1]; rescale keeps E close to k.
    k = density * p
    probs = jnp.where(denom > 0, jnp.minimum(1.0, k * absv / denom),
                      jnp.zeros_like(v))
    keep = jax.random.uniform(key, v.shape) < probs
    out = jnp.where(keep, v / jnp.maximum(probs, 1e-12), 0.0)
    nnz = jnp.sum(keep.astype(jnp.float32))
    # wire: 32-bit value + index (ceil(log2 p) bits) per surviving coordinate
    idx_bits = max(1, int(math.ceil(math.log2(p))))
    wire_bits = nnz * (32.0 + idx_bits)
    return _unflat(out, meta), wire_bits
