"""Simulated M-worker cluster — drives the paper-reproduction experiments.

Runs the worker/server protocol on a single device with a leading worker axis
(vmap), which is exactly the paper's M=10 setting.  Production execution on a
real mesh lives in ``repro/launch/train.py``; both share the per-worker math
in ``core/strategy.py``.

The quantize pipeline inside each round is pluggable via
``StrategyConfig.wire_backend`` (core/wire.py): ``"reference"`` runs the
paper-faithful jnp sweeps, ``"fused"`` the two-pass pipeline (Pallas on TPU,
blocked jnp on CPU) whose wire content is bit-identical — so a whole
simulated run reproduces the same trajectory on either backend.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compressors import qsgd_compress, ssgd_compress
from .quantize import dense_bits, tree_size, tree_sq_norm
from .strategy import (CommState, RoundMetrics, StrategyConfig, aggregate,
                       finalize_step, init_comm_state)

Pytree = object


class RunResult(NamedTuple):
    params: Pytree
    loss: jax.Array          # [K] global loss per iteration
    grad_norm_sq: jax.Array  # [K]
    cum_uploads: jax.Array   # [K] cumulative communication rounds
    cum_bits: jax.Array      # [K] cumulative wire bits
    quant_err: jax.Array     # [K] max_m R_m (decay diagnostic, paper Fig. 3)
    mean_bits: jax.Array = None  # [K] mean selected width over uploaders
                                 # (adaptive-LAQ diagnostic; static otherwise)


def run_gradient_based(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                       cfg: StrategyConfig, *, steps: int, alpha: float) -> RunResult:
    """Deterministic full-gradient methods: GD / QGD / LAG / LAQ.

    ``loss_fn(params, data_shard) -> scalar`` is one worker's local loss
    f_m; ``worker_data`` has a leading worker axis W.  Global objective is
    ``sum_m f_m`` (paper eq. 1).
    """
    n_workers = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    grad_m = jax.grad(loss_fn)

    def global_loss(p):
        return jnp.sum(jax.vmap(lambda d: loss_fn(p, d))(worker_data))

    state0 = init_comm_state(params0, n_workers, cfg)

    def step(carry, _):
        params, cst = carry
        grads = jax.vmap(lambda d: grad_m(params, d))(worker_data)
        agg, cst, metrics = aggregate(cst, grads, alpha, cfg, params=params)
        new_params = jax.tree.map(lambda t, g: t - alpha * g, params, agg)
        dtheta_sq = tree_sq_norm(jax.tree.map(lambda a, b: a - b, new_params, params))
        cst = finalize_step(cst, dtheta_sq)
        gn = tree_sq_norm(jax.grad(global_loss)(params))
        rec = (global_loss(params), gn, cst.total_uploads, cst.total_bits,
               metrics.radius_max, metrics.mean_bits)
        return (new_params, cst), rec

    (params, _), recs = jax.lax.scan(step, (params0, state0), None, length=steps)
    loss, gn, cu, cb, qe, mb = recs
    return RunResult(params, loss, gn, cu, cb, qe, mb)


def run_stochastic(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                   kind: str, *, steps: int, alpha: float, batch: int,
                   bits: int = 3, density: float = 0.1,
                   seed: int = 0,
                   laq_cfg: Optional[StrategyConfig] = None) -> RunResult:
    """Minibatch methods of Table 3: SGD / QSGD / SSGD / SLAQ.

    Each worker samples ``batch`` local examples per step.  For the SLAQ
    family the LAQ state machine runs on the stochastic gradients, with the
    skip criterion picked by ``laq_cfg.lazy_rule`` (core/lazy_rules.py):

    * ``kind="slaq"``    — ``laq_cfg`` as given (default rule: paper eq. 7a,
      i.e. LAQ-on-noisy-gradients, the LASG paper's strawman);
    * ``kind="slaq_wk"`` — forces the variance-corrected worker-side rule
      (``lazy_rule="lasg_wk"``);
    * ``kind="slaq_ps"`` — forces the server-side parameter-drift rule
      (``lazy_rule="lasg_ps"``).
    """
    n_workers = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    n_local = jax.tree_util.tree_leaves(worker_data)[0].shape[1]
    grad_m = jax.grad(loss_fn)
    p = tree_size(params0)

    def global_loss(pp):
        return jnp.sum(jax.vmap(lambda d: loss_fn(pp, d))(worker_data))

    slaq_rules = {"slaq": None, "slaq_wk": "lasg_wk", "slaq_ps": "lasg_ps"}
    if kind in slaq_rules:
        scfg = laq_cfg or StrategyConfig(kind="laq", bits=bits)
        if slaq_rules[kind] is not None:
            scfg = scfg._replace(lazy_rule=slaq_rules[kind])
        state0 = init_comm_state(params0, n_workers, scfg)
    else:
        state0 = init_comm_state(params0, n_workers,
                                 StrategyConfig(kind="gd"))  # bits bookkeeping only

    key0 = jax.random.PRNGKey(seed)

    def sample(data_m, key):
        idx = jax.random.randint(key, (batch,), 0, n_local)
        return jax.tree.map(lambda x: x[idx], data_m)

    def step(carry, _):
        params, cst, key = carry
        key, k_idx, k_cmp = jax.random.split(key, 3)
        keys_idx = jax.random.split(k_idx, n_workers)
        batches = jax.vmap(sample)(worker_data, keys_idx)
        # worker gradients scaled so that sum_m E[g_m] = grad of global loss
        scale = n_local / batch
        grads = jax.vmap(lambda b: jax.tree.map(lambda g: g * scale,
                                                grad_m(params, b)))(batches)

        if kind in slaq_rules:
            agg, cst, metrics = aggregate(cst, grads, alpha, scfg,
                                          params=params)
            qe = metrics.radius_max
            mb = metrics.mean_bits
        else:
            keys_cmp = jax.random.split(k_cmp, n_workers)
            if kind == "sgd":
                cgrads = grads
                bits_m = jnp.full((n_workers,), float(dense_bits(p)))
            elif kind == "qsgd":
                cgrads, bits_m = jax.vmap(lambda k, g: qsgd_compress(k, g, bits))(keys_cmp, grads)
            elif kind == "ssgd":
                cgrads, bits_m = jax.vmap(lambda k, g: ssgd_compress(k, g, density))(keys_cmp, grads)
            else:
                raise ValueError(kind)
            agg = jax.tree.map(lambda g: jnp.sum(g, axis=0), cgrads)
            cst = cst._replace(total_bits=cst.total_bits + jnp.sum(bits_m),
                               total_uploads=cst.total_uploads + n_workers,
                               step=cst.step + 1)
            qe = jnp.zeros(())
            mb = jnp.mean(bits_m) / p

        new_params = jax.tree.map(lambda t, g: t - alpha * g, params, agg)
        if kind in slaq_rules:
            dsq = tree_sq_norm(jax.tree.map(lambda a, b: a - b, new_params, params))
            cst = finalize_step(cst, dsq)
        gn = tree_sq_norm(jax.grad(global_loss)(params))
        rec = (global_loss(params), gn, cst.total_uploads, cst.total_bits, qe, mb)
        return (new_params, cst, key), rec

    (params, _, _), recs = jax.lax.scan(step, (params0, state0, key0), None, length=steps)
    loss, gn, cu, cb, qe, mb = recs
    return RunResult(params, loss, gn, cu, cb, qe, mb)
