"""Simulated M-worker cluster — drives the paper-reproduction experiments.

Runs the worker/server protocol on a single device with a leading worker axis
(vmap), which is exactly the paper's M=10 setting.  Production execution on a
real mesh lives in ``repro/launch/train.py``; both share the per-worker math
in ``core/strategy.py``.

The quantize pipeline inside each round is pluggable via
``StrategyConfig.wire_backend`` (core/wire.py): ``"reference"`` runs the
paper-faithful jnp sweeps, ``"fused"`` the two-pass pipeline (Pallas on TPU,
blocked jnp on CPU) whose wire content is bit-identical — so a whole
simulated run reproduces the same trajectory on either backend.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adaptive import eta_at
from .compressors import qsgd_compress, ssgd_compress
from .quantize import dense_bits, tree_size, tree_sq_norm
from .strategy import (CommState, RoundMetrics, StrategyConfig, SvrgState,
                       aggregate, finalize_step, init_comm_state)

Pytree = object


class RunResult(NamedTuple):
    params: Pytree
    loss: jax.Array          # [K] global loss per iteration
    grad_norm_sq: jax.Array  # [K]
    cum_uploads: jax.Array   # [K] cumulative communication rounds
    cum_bits: jax.Array      # [K] cumulative wire bits
    quant_err: jax.Array     # [K] max_m R_m (decay diagnostic, paper Fig. 3)
    mean_bits: jax.Array = None  # [K] mean selected width over uploaders
                                 # (adaptive-LAQ diagnostic; static otherwise)


def run_gradient_based(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                       cfg: StrategyConfig, *, steps: int, alpha: float) -> RunResult:
    """Deterministic full-gradient methods: GD / QGD / LAG / LAQ.

    ``loss_fn(params, data_shard) -> scalar`` is one worker's local loss
    f_m; ``worker_data`` has a leading worker axis W.  Global objective is
    ``sum_m f_m`` (paper eq. 1).
    """
    n_workers = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    grad_m = jax.grad(loss_fn)

    def global_loss(p):
        return jnp.sum(jax.vmap(lambda d: loss_fn(p, d))(worker_data))

    state0 = init_comm_state(params0, n_workers, cfg)
    wk2 = cfg.lazy and cfg.lazy_rule == "lasg_wk2"

    def step(carry, _):
        params, cst = carry
        alpha_k = eta_at(cfg.eta_schedule, alpha, cst.step)
        grads = jax.vmap(lambda d: grad_m(params, d))(worker_data)
        grads_stale = None
        if wk2:
            # deterministic WK2: the full local gradient at the stale
            # iterate (no noise to cancel — the rule degenerates to LAG's
            # exact gradient-difference trigger, at 2x compute)
            grads_stale = jax.vmap(lambda t, d: grad_m(t, d))(
                cst.lazy.theta_last, worker_data)
        agg, cst, metrics = aggregate(cst, grads, alpha_k, cfg,
                                      params=params, grads_stale=grads_stale)
        new_params = jax.tree.map(lambda t, g: t - alpha_k * g, params, agg)
        dtheta_sq = tree_sq_norm(jax.tree.map(lambda a, b: a - b, new_params, params))
        cst = finalize_step(cst, dtheta_sq)
        gn = tree_sq_norm(jax.grad(global_loss)(params))
        rec = (global_loss(params), gn, cst.total_uploads, cst.total_bits,
               metrics.radius_max, metrics.mean_bits)
        return (new_params, cst), rec

    (params, _), recs = jax.lax.scan(step, (params0, state0), None, length=steps)
    loss, gn, cu, cb, qe, mb = recs
    return RunResult(params, loss, gn, cu, cb, qe, mb)


def run_stochastic(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                   kind: str, *, steps: int, alpha: float, batch: int,
                   bits: int = 3, density: float = 0.1,
                   seed: int = 0,
                   laq_cfg: Optional[StrategyConfig] = None) -> RunResult:
    """Minibatch methods of Table 3: SGD / QSGD / SSGD / SLAQ.

    Each worker samples ``batch`` local examples per step.  For the SLAQ
    family the LAQ state machine runs on the stochastic gradients, with the
    skip criterion picked by ``laq_cfg.lazy_rule`` (core/lazy_rules.py):

    * ``kind="slaq"``     — ``laq_cfg`` as given (default rule: paper eq. 7a,
      i.e. LAQ-on-noisy-gradients, the LASG paper's strawman);
    * ``kind="slaq_wk"``  — forces the variance-corrected worker-side rule
      (``lazy_rule="lasg_wk"``);
    * ``kind="slaq_wk2"`` — forces the same-sample noise-free rule
      (``lazy_rule="lasg_wk2"``; the runner pays the second backprop of the
      current minibatch at each worker's stale iterate);
    * ``kind="slaq_ps"``  — forces the server-side parameter-drift rule
      (``lazy_rule="lasg_ps"``).

    Two further levers apply to EVERY kind (baselines inherit them from
    ``laq_cfg`` so frontier comparisons stay matched):

    * ``laq_cfg.grad_mode="svrg"`` — variance-reduced local gradients: each
      worker keeps a periodic full-local-gradient anchor (``CommState.svrg``,
      refreshed every ``svrg_period`` rounds inside a ``lax.cond``) and feeds
      the corrected minibatch gradient to the lazy rule and the quantizer;
    * ``laq_cfg.eta_schedule`` — the per-round stepsize (constant / 1-over-t
      / stagewise halving), applied to the update *and* the criterion.

    RNG discipline (determinism-regression-tested): every key derives
    functionally from ``(seed, stream, round, worker)`` by ``fold_in`` — no
    carried split chain — so the minibatch sequence is bit-identical across
    kinds (compressor kinds draw from their own stream without perturbing
    the batch stream) and each worker's stream is independent.
    """
    n_workers = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    n_local = jax.tree_util.tree_leaves(worker_data)[0].shape[1]
    grad_m = jax.grad(loss_fn)
    p = tree_size(params0)

    def global_loss(pp):
        return jnp.sum(jax.vmap(lambda d: loss_fn(pp, d))(worker_data))

    slaq_rules = {"slaq": None, "slaq_wk": "lasg_wk", "slaq_wk2": "lasg_wk2",
                  "slaq_ps": "lasg_ps"}
    is_slaq = kind in slaq_rules
    if is_slaq:
        scfg = laq_cfg or StrategyConfig(kind="laq", bits=bits)
        if slaq_rules[kind] is not None:
            scfg = scfg._replace(lazy_rule=slaq_rules[kind])
    else:
        if kind not in ("sgd", "qsgd", "ssgd"):
            raise ValueError(kind)
        # bits bookkeeping only — but the stochastic levers (grad_mode,
        # eta_schedule) carry over so baselines are variance-matched
        src = laq_cfg or StrategyConfig()
        scfg = StrategyConfig(kind="gd", grad_mode=src.grad_mode,
                              svrg_period=src.svrg_period,
                              eta_schedule=src.eta_schedule)
    state0 = init_comm_state(params0, n_workers, scfg)
    wk2 = is_slaq and scfg.lazy and scfg.lazy_rule == "lasg_wk2"

    key0 = jax.random.PRNGKey(seed)
    worker_ids = jnp.arange(n_workers)

    def stream_keys(stream, step_idx):
        ks = jax.random.fold_in(jax.random.fold_in(key0, stream), step_idx)
        return jax.vmap(lambda m: jax.random.fold_in(ks, m))(worker_ids)

    def sample(data_m, key):
        idx = jax.random.randint(key, (batch,), 0, n_local)
        return jax.tree.map(lambda x: x[idx], data_m)

    # worker gradients scaled so that sum_m E[g_m] = grad of global loss
    scale = n_local / batch

    def grads_at(thetas, batches):
        """Per-worker scaled minibatch gradients at per-worker iterates."""
        return jax.vmap(lambda t, b: jax.tree.map(
            lambda g: g.astype(jnp.float32) * scale, grad_m(t, b)))(thetas, batches)

    def broadcast_w(tree):
        return jax.tree.map(lambda l: jnp.broadcast_to(
            l.astype(jnp.float32), (n_workers,) + l.shape), tree)

    def svrg_refresh(params, svrg):
        # anchor <- current iterate; mu <- exact full LOCAL gradient there
        # (already on the global-loss scale: loss_fn normalizes by N)
        mu = jax.vmap(lambda d: grad_m(params, d))(worker_data)
        return SvrgState(
            theta_anchor=broadcast_w(params),
            mu_anchor=jax.tree.map(lambda g: g.astype(jnp.float32), mu))

    def step(carry, _):
        params, cst = carry
        alpha_k = eta_at(scfg.eta_schedule, alpha, cst.step)
        batches = jax.vmap(sample)(worker_data, stream_keys(0, cst.step))
        grads = grads_at(broadcast_w(params), batches)

        corr = None
        if scfg.variance_reduced:
            svrg = jax.lax.cond(cst.step % scfg.svrg_period == 0,
                                lambda s: svrg_refresh(params, s),
                                lambda s: s, cst.svrg)
            cst = cst._replace(svrg=svrg)
            # additive SVRG correction mu - (n/B) g(theta_anchor; xi): the
            # SAME term is applied to the stale-side WK2 gradient below, so
            # anchor and mu cancel in the same-sample difference
            g_anchor = grads_at(svrg.theta_anchor, batches)
            corr = jax.tree.map(lambda mu, ga: mu - ga,
                                svrg.mu_anchor, g_anchor)
            grads = jax.tree.map(lambda g, c: g + c, grads, corr)

        if is_slaq:
            grads_stale = None
            if wk2:
                # the second backprop: the SAME minibatch at the stale iterate
                grads_stale = grads_at(cst.lazy.theta_last, batches)
                if corr is not None:
                    grads_stale = jax.tree.map(lambda g, c: g + c,
                                               grads_stale, corr)
            agg, cst, metrics = aggregate(cst, grads, alpha_k, scfg,
                                          params=params,
                                          grads_stale=grads_stale)
            qe = metrics.radius_max
            mb = metrics.mean_bits
        else:
            keys_cmp = stream_keys(1, cst.step)
            if kind == "sgd":
                cgrads = grads
                bits_m = jnp.full((n_workers,), float(dense_bits(p)))
            elif kind == "qsgd":
                cgrads, bits_m = jax.vmap(lambda k, g: qsgd_compress(k, g, bits))(keys_cmp, grads)
            else:
                cgrads, bits_m = jax.vmap(lambda k, g: ssgd_compress(k, g, density))(keys_cmp, grads)
            agg = jax.tree.map(lambda g: jnp.sum(g, axis=0), cgrads)
            cst = cst._replace(total_bits=cst.total_bits + jnp.sum(bits_m),
                               total_uploads=cst.total_uploads + n_workers,
                               step=cst.step + 1)
            qe = jnp.zeros(())
            mb = jnp.mean(bits_m) / p

        new_params = jax.tree.map(lambda t, g: t - alpha_k * g, params, agg)
        if is_slaq:
            dsq = tree_sq_norm(jax.tree.map(lambda a, b: a - b, new_params, params))
            cst = finalize_step(cst, dsq)
        gn = tree_sq_norm(jax.grad(global_loss)(params))
        rec = (global_loss(params), gn, cst.total_uploads, cst.total_bits, qe, mb)
        return (new_params, cst), rec

    (params, _), recs = jax.lax.scan(step, (params0, state0), None, length=steps)
    loss, gn, cu, cb, qe, mb = recs
    return RunResult(params, loss, gn, cu, cb, qe, mb)
