"""Simulated M-worker cluster — drives the paper-reproduction experiments.

Runs the worker/server protocol on a single device with a leading worker axis
(vmap), which is exactly the paper's M=10 setting.  Production execution on a
real mesh lives in ``repro/launch/train.py``; both share the per-worker math
in ``core/strategy.py`` **and the round stages in ``core/engine.py``** — the
two runners here are thin, backward-compatible wrappers over
:class:`repro.core.engine.RoundEngine` and reproduce their pre-engine
trajectories bitwise (tests/test_engine_parity.py).

The quantize pipeline inside each round is pluggable via
``StrategyConfig.wire_backend`` (core/wire.py); which workers the server
reaches each round via ``StrategyConfig.participation`` /
``participation_p`` / ``max_delay`` / ``markov_sojourn`` (core/engine.py
participation models — client sampling, bounded-staleness async workers
and bursty Markov churn compose with every kind and lazy rule below).
Fault injection (``StrategyConfig.faults``, core/faults.py) and the
defense stack (``StrategyConfig.defense`` / ``aggregator``,
core/defense.py) run here in full — corruption, crash-restart and robust
aggregation are simulated-engine-only; see docs/robustness.md.
"""
from __future__ import annotations

from typing import Callable, Optional

from .engine import FullBatchSource, MinibatchSource, RoundEngine, RunResult
from .strategy import StrategyConfig

Pytree = object

__all__ = ["RunResult", "run_gradient_based", "run_stochastic"]

# kind -> forced lazy_rule for the stochastic LAQ family (None = as given)
_SLAQ_RULES = {"slaq": None, "slaq_wk": "lasg_wk", "slaq_wk2": "lasg_wk2",
               "slaq_ps": "lasg_ps"}


def run_gradient_based(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                       cfg: StrategyConfig, *, steps: int, alpha: float) -> RunResult:
    """Deterministic full-gradient methods: GD / QGD / LAG / LAQ.

    ``loss_fn(params, data_shard) -> scalar`` is one worker's local loss
    f_m; ``worker_data`` has a leading worker axis W.  Global objective is
    ``sum_m f_m`` (paper eq. 1).
    """
    source = FullBatchSource(loss_fn, worker_data)
    return RoundEngine(source, cfg, alpha=alpha).run(params0, steps)


def run_stochastic(loss_fn: Callable, params0: Pytree, worker_data: Pytree,
                   kind: str, *, steps: int, alpha: float, batch: int,
                   bits: int = 3, density: float = 0.1,
                   seed: int = 0,
                   laq_cfg: Optional[StrategyConfig] = None) -> RunResult:
    """Minibatch methods of Table 3: SGD / QSGD / SSGD / SLAQ.

    Each worker samples ``batch`` local examples per step.  For the SLAQ
    family the LAQ state machine runs on the stochastic gradients, with the
    skip criterion picked by ``laq_cfg.lazy_rule`` (core/lazy_rules.py):

    * ``kind="slaq"``     — ``laq_cfg`` as given (default rule: paper eq. 7a,
      i.e. LAQ-on-noisy-gradients, the LASG paper's strawman);
    * ``kind="slaq_wk"``  — forces the variance-corrected worker-side rule
      (``lazy_rule="lasg_wk"``);
    * ``kind="slaq_wk2"`` — forces the same-sample noise-free rule
      (``lazy_rule="lasg_wk2"``; the engine pays the second backprop of the
      current minibatch at each worker's stale iterate);
    * ``kind="slaq_ps"``  — forces the server-side parameter-drift rule
      (``lazy_rule="lasg_ps"``).

    Three further levers apply to EVERY kind (baselines inherit them from
    ``laq_cfg`` so frontier comparisons stay matched):

    * ``laq_cfg.grad_mode="svrg"`` — variance-reduced local gradients
      (:func:`repro.core.engine.apply_svrg_exact`: per-worker periodic
      full-local-gradient anchors in ``CommState.svrg``);
    * ``laq_cfg.eta_schedule`` — the per-round stepsize (constant / 1-over-t
      / stagewise halving), applied to the update *and* the criterion;
    * ``laq_cfg.participation`` / ``participation_p`` / ``max_delay`` —
      client sampling / bounded-staleness participation (core/engine.py).

    RNG discipline (determinism-regression-tested): every key derives
    functionally from ``(seed, stream, round, worker)`` by ``fold_in`` — no
    carried split chain — so the minibatch sequence is bit-identical across
    kinds (compressor kinds draw from their own stream without perturbing
    the batch stream) and each worker's stream is independent.
    """
    is_slaq = kind in _SLAQ_RULES
    if is_slaq:
        scfg = laq_cfg or StrategyConfig(kind="laq", bits=bits)
        if _SLAQ_RULES[kind] is not None:
            scfg = scfg._replace(lazy_rule=_SLAQ_RULES[kind])
        baseline = None
    else:
        if kind not in ("sgd", "qsgd", "ssgd"):
            raise ValueError(kind)
        # bits bookkeeping only — but the stochastic levers (grad_mode,
        # eta_schedule, participation) carry over so baselines stay matched
        src = laq_cfg or StrategyConfig()
        scfg = StrategyConfig(kind="gd", grad_mode=src.grad_mode,
                              svrg_period=src.svrg_period,
                              eta_schedule=src.eta_schedule,
                              participation=src.participation,
                              participation_p=src.participation_p,
                              max_delay=src.max_delay,
                              participation_seed=src.participation_seed)
        baseline = kind
    source = MinibatchSource(loss_fn, worker_data, batch=batch, seed=seed)
    engine = RoundEngine(source, scfg, alpha=alpha, baseline=baseline,
                         bits=bits, density=density, track_history=is_slaq)
    return engine.run(params0, steps)
