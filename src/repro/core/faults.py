"""Fault injection: corrupt, crashed and Byzantine workers (core/defense.py
holds the countermeasures).

Everything the engine models up to PR 6 is *benign*: the participation layer
covers workers that are absent or late, but not workers that misbehave.
This module is the injection half of the robustness subsystem — a pluggable
:class:`FaultConfig` riding in ``StrategyConfig.faults`` that the
``RoundEngine`` applies each round, with the same deterministic stream
discipline as ``participation_mask``: every fault is a pure function of
``(fault_seed, stream, step, worker)`` via ``fold_in``, independent of the
batch / compressor / participation streams, so faulty runs are exactly
reproducible and replayable (which the divergence watchdog's rollback
depends on).

Three fault families, selected per-worker per-round:

* **payload corruption** (``corrupt_p`` / ``corrupt_kind``) — the worker's
  outgoing gradient is damaged before encoding: ``"nan"`` / ``"inf"``
  poison, ``"sign_flip"``, ``"scale"`` (Byzantine gradient-scaling attack,
  factor ``corrupt_scale``), or ``"bitflip"`` — MSB flips on a
  ``bitflip_frac`` fraction of the *packed wire codes* themselves (applied
  inside ``worker_update`` on the quantized payload via the exact
  code-space inverse maps in :mod:`repro.core.wire`).  Corruption happens
  at the worker, after the (honest) skip decision: the damaged payload is
  what both the server aggregate AND the worker's own ``qhat`` mirror
  commit, so the two views stay consistent — exactly the failure mode a
  real corrupt sender produces.

* **crash-restart** (``crash_p``) — the worker loses its entire per-worker
  state (``qhat``, ``LazyState``, ``SvrgState``, ``ErrorState``, threshold
  anchor) and re-bootstraps through the existing first-upload machinery:
  its clock restarts at ``t_bar`` so criterion (7b) forces a dense
  re-upload, and the LASG bootstrap guards (``stat_count == 0``) force the
  estimator rules to upload too.  The server may *reconcile* the crash
  (subtract the stale ``qhat_m`` from ``server_agg``, keeping the
  recursion invariant ``server_agg == sum_m qhat_m``) — without
  reconciliation the dead contribution biases every subsequent round, the
  failure ``benchmarks/fault_frontier.py`` measures.

* **Markov-churn availability** — lives with the other participation
  models in :mod:`repro.core.engine` (``participation="markov"``); it is a
  fault in the availability process, not in the payload, so it composes
  with the families above rather than belonging to them.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .wire import codes_of_delta, delta_of_codes

Pytree = object

CORRUPT_KINDS = ("nan", "inf", "sign_flip", "scale", "bitflip")

# fold_in stream ids under PRNGKey(fault_seed) — disjoint by construction
_STREAM_CORRUPT = 0
_STREAM_CRASH = 1
_STREAM_BITFLIP = 2


class FaultConfig(NamedTuple):
    """Static fault-injection knobs (``StrategyConfig.faults``).

    All-zero probabilities (the default) make every fault path a static
    no-op: the engine compiles the exact pre-fault round, so fault-free
    trajectories stay bitwise identical to the pre-robustness code.
    """
    corrupt_p: float = 0.0      # per-worker per-round payload-corruption prob
    corrupt_kind: str = "nan"   # one of CORRUPT_KINDS
    corrupt_scale: float = 50.0  # multiplier of the "scale" Byzantine fault
    bitflip_frac: float = 0.05  # fraction of wire codes MSB-flipped per
                                # corrupted upload ("bitflip" kind)
    crash_p: float = 0.0        # per-worker per-round crash-restart prob
    fault_seed: int = 0         # seed of the fault streams (independent of
                                # batch / compressor / participation RNG)

    @property
    def active(self) -> bool:
        return self.corrupt_p > 0.0 or self.crash_p > 0.0

    @property
    def grad_faulty(self) -> bool:
        """Gradient-level corruption (applied by the engine before encode)."""
        return self.corrupt_p > 0.0 and self.corrupt_kind != "bitflip"

    @property
    def wire_faulty(self) -> bool:
        """Code-level corruption (applied inside ``worker_update``)."""
        return self.corrupt_p > 0.0 and self.corrupt_kind == "bitflip"

    @property
    def crashy(self) -> bool:
        return self.crash_p > 0.0


def _stream_key(fc: FaultConfig, stream: int, step):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(fc.fault_seed), stream), step)


def corruption_mask(fc: FaultConfig, step, n_workers: int) -> jax.Array:
    """[W] bool: which workers emit a corrupted payload this round."""
    return jax.random.bernoulli(_stream_key(fc, _STREAM_CORRUPT, step),
                                fc.corrupt_p, (n_workers,))


def crash_mask(fc: FaultConfig, step, n_workers: int) -> jax.Array:
    """[W] bool: which workers crash-restart at the START of this round."""
    return jax.random.bernoulli(_stream_key(fc, _STREAM_CRASH, step),
                                fc.crash_p, (n_workers,))


def bitflip_keys(fc: FaultConfig, step, n_workers: int) -> jax.Array:
    """[W] per-worker keys for the wire-code flip positions."""
    ks = _stream_key(fc, _STREAM_BITFLIP, step)
    return jax.vmap(lambda m: jax.random.fold_in(ks, m))(
        jnp.arange(n_workers))


def corrupt_grads(grads: Pytree, mask: jax.Array, fc: FaultConfig) -> Pytree:
    """Apply a gradient-level fault to the masked workers' gradients.

    ``grads`` carries a leading worker axis W; ``mask`` is [W] bool.  The
    whole gradient of a corrupted worker is damaged (a faulty sender, not a
    faulty coordinate).
    """
    kind = fc.corrupt_kind
    assert kind in CORRUPT_KINDS and kind != "bitflip", kind

    def leaf(g):
        g = g.astype(jnp.float32)
        mb = mask.reshape((-1,) + (1,) * (g.ndim - 1))
        if kind == "nan":
            bad = jnp.full_like(g, jnp.nan)
        elif kind == "inf":
            bad = jnp.full_like(g, jnp.inf)
        elif kind == "sign_flip":
            bad = -g
        else:   # "scale"
            bad = fc.corrupt_scale * g
        return jnp.where(mb, bad, g)

    return jax.tree.map(leaf, grads)


def flip_wire_codes(delta: Pytree, R_tree: Pytree, bits: int, key,
                    frac: float) -> Pytree:
    """MSB-flip a ``frac`` fraction of one worker's wire codes.

    Round-trips the dequantized ``delta`` through the exact code-space
    inverse maps (:func:`repro.core.wire.codes_of_delta`), XORs the top bit
    of the keyed coordinate subset — each flip moves the coordinate by
    half the code range, ``2 tau R 2^{b-1} ~= R`` — and re-emits the
    corrupted dequantized innovation.  Positions derive from ``key`` (one
    per worker from :func:`bitflip_keys`) folded with the leaf index.
    """
    leaves, treedef = jax.tree_util.tree_flatten(delta)
    r_leaves = jax.tree_util.tree_leaves(R_tree)
    msb = jnp.uint8(1 << (bits - 1))
    out = []
    for i, (d, R) in enumerate(zip(leaves, r_leaves)):
        if d.size == 0:
            out.append(d)
            continue
        q = codes_of_delta(d, R, bits)
        u = jax.random.uniform(jax.random.fold_in(key, i), d.shape)
        q = jnp.where(u < frac, q ^ msb, q)
        out.append(delta_of_codes(q, R, bits))
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_crashes(cst, mask: jax.Array, params: Pytree, grads: Pytree,
                  cfg, *, reconcile: bool = True):
    """Reset the per-worker state of crashed workers (start of round).

    ``cst`` is the simulated-mode :class:`~repro.core.strategy.CommState`
    (leading worker dim); ``mask`` is [W] bool; ``params`` the current
    iterate (the restarted worker's fresh snapshots); ``grads`` this
    round's per-worker gradients (the restarted SVRG anchor's ``mu`` — a
    streaming-style refresh, same documented degradation as the sharded
    path).  ``cfg`` is the ``StrategyConfig`` (for ``criterion.t_bar``).

    A crashed worker loses ``qhat`` / ``eps_hat_sq`` / ``LazyState`` /
    ``SvrgState`` / ``ErrorState`` / ``R_anchor`` and restarts its clock at
    ``t_bar``, so the existing first-upload guard — criterion (7b) plus the
    LASG ``stat_count == 0`` bootstrap guards — forces a dense re-upload at
    its next reachable round.  With ``reconcile`` (the defended server) the
    stale ``qhat_m`` is subtracted from ``server_agg``, preserving the
    recursion invariant ``server_agg == sum_m qhat_m``; without it the dead
    contribution stays in the aggregate forever (the undefended failure
    mode ``benchmarks/fault_frontier.py`` demonstrates).  Server-side
    ledgers (``bits_spent``, totals, the defense state) are NOT reset: the
    server never lost them.
    """
    fm = mask.astype(jnp.float32)

    def wsel(reset_leaf, old_leaf):
        mb = mask.reshape((-1,) + (1,) * (old_leaf.ndim - 1))
        return jnp.where(mb, reset_leaf.astype(old_leaf.dtype), old_leaf)

    def wzero(old):
        return jax.tree.map(lambda l: wsel(jnp.zeros_like(l, jnp.float32), l),
                            old)

    def wsnap(old):
        # per-worker snapshot of the current (replicated) params
        return jax.tree.map(
            lambda l, p_: wsel(jnp.broadcast_to(p_.astype(jnp.float32),
                                                l.shape), l),
            old, params)

    qhat_old = cst.qhat
    new = {
        "qhat": wzero(qhat_old),
        "eps_hat_sq": jnp.where(mask, 0.0, cst.eps_hat_sq),
        "clocks": jnp.where(mask, cfg.criterion.t_bar,
                            cst.clocks).astype(jnp.int32),
        "R_anchor": jnp.where(mask, 0.0, cst.R_anchor),
    }
    if reconcile:
        new["server_agg"] = jax.tree.map(
            lambda a, q: (a.astype(jnp.float32)
                          - jnp.sum(fm.reshape((-1,) + (1,) * (q.ndim - 1))
                                    * q.astype(jnp.float32), axis=0)
                          ).astype(a.dtype),
            cst.server_agg, qhat_old)

    lz = cst.lazy
    new["lazy"] = lz._replace(
        grad_ema=None if lz.grad_ema is None else wzero(lz.grad_ema),
        stat_ema=jnp.where(mask, 0.0, lz.stat_ema),
        stat_count=jnp.where(mask, 0.0, lz.stat_count),
        sigma_hat_sq=jnp.where(mask, 0.0, lz.sigma_hat_sq),
        theta_last=None if lz.theta_last is None else wsnap(lz.theta_last))
    sv = cst.svrg
    if sv.theta_anchor is not None:
        new["svrg"] = sv._replace(
            theta_anchor=wsnap(sv.theta_anchor),
            mu_anchor=jax.tree.map(wsel, grads, sv.mu_anchor))
    er = cst.error
    if er.residual is not None:
        new["error"] = er._replace(residual=wzero(er.residual))
    return cst._replace(**new)
