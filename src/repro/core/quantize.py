"""Gradient-innovation quantizer (paper eq. 5-6).

The paper quantizes the *innovation* ``g - q_hat`` (fresh local gradient minus
the previously uploaded quantized gradient) onto a uniform b-bit grid whose
radius is the innovation's infinity-norm ``R``.  The wire format per upload is
``32 + b*p`` bits: one float32 for ``R`` plus ``b`` bits per coordinate.

All functions operate on pytrees so the "gradient vector" of the paper maps
directly onto a model's parameter pytree.  A single global radius ``R`` is
used across the whole pytree, exactly as the paper uses one radius for the
whole p-dimensional gradient.

The physical byte layout (packing order, padding, sidecars, adaptive width
announcement) is specified normatively in ``docs/wire-format.md``; the
packing helpers below implement that spec.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Pytree = object


def tree_inf_norm(tree: Pytree) -> jax.Array:
    """Global infinity norm over a pytree (the paper's ``R_m^k``)."""
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if l.size]
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.max(jnp.stack([jnp.max(jnp.abs(l)).astype(jnp.float32) for l in leaves]))


def tree_sq_norm(tree: Pytree) -> jax.Array:
    """Global squared L2 norm over a pytree."""
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if l.size]
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sum(jnp.stack([jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves]))


def tree_size(tree: Pytree) -> int:
    """Total number of coordinates p."""
    return sum(l.size for l in jax.tree_util.tree_leaves(tree))


def tau(bits: int) -> float:
    """Quantization granularity tau = 1/(2^b - 1)."""
    return 1.0 / (2.0**bits - 1.0)


def innovation(grad: Pytree, qhat: Pytree, per_leaf: bool = False):
    """``(diff, R_tree, R_max)`` for the innovation ``grad - qhat``.

    The single source of the radius logic shared by the fixed-bit quantizer
    below and the dynamic-width quantizer in :mod:`repro.core.adaptive`
    (their bit-exact equivalence depends on this being one implementation).
    """
    diff = jax.tree.map(
        lambda g, q: g.astype(jnp.float32) - q.astype(jnp.float32), grad, qhat)
    if per_leaf:
        R_tree = jax.tree.map(
            lambda d: (jnp.max(jnp.abs(d)).astype(jnp.float32)
                       if d.size else jnp.zeros((), jnp.float32)), diff)
    else:
        R = tree_inf_norm(diff)
        R_tree = jax.tree.map(lambda _: R, diff)
    R_max = jnp.max(jnp.stack(jax.tree_util.tree_leaves(R_tree)))
    return diff, R_tree, R_max


def quantize_codes(d: jax.Array, R: jax.Array, bits: int) -> jax.Array:
    """Per-leaf quantization codes (paper eq. 5) for one static width:

        q_i = floor( (d_i + R) / (2 tau R) + 1/2 ),  clipped to [0, 2^b - 1]

    R == 0 -> innovation identically zero -> midpoint code (dequantizes to 0).
    """
    t = tau(bits)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((d + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q))
    return q.astype(jnp.uint8 if bits <= 8 else jnp.int32)


def quantize_innovation(grad: Pytree, qhat: Pytree, bits: int,
                        per_leaf: bool = False):
    """Quantize ``grad`` against the previous quantized gradient ``qhat``.

    Returns ``(qints, R_tree)`` where ``qints`` is a pytree of integer codes
    in ``[0, 2^b - 1]`` (stored as uint8 for b <= 8) and ``R_tree`` mirrors
    the pytree with per-leaf scalar radii.

    ``per_leaf=False`` is the paper-faithful mode: a single global radius
    (one 32-bit sidecar on the wire), replicated into every leaf of
    ``R_tree``.  ``per_leaf=True`` is bucketed quantization (one radius per
    parameter tensor, ``32 * n_leaves`` sidecar bits) — at large p the global
    infinity-norm is dominated by a few embedding/head coordinates and the
    grid becomes uselessly coarse for everything else; bucketing is the
    standard production fix (recorded as a beyond-paper change).
    """
    diff, R_tree, _ = innovation(grad, qhat, per_leaf)
    qints = jax.tree.map(lambda d, R: quantize_codes(d, R, bits), diff, R_tree)
    return qints, R_tree


def dequantize_innovation(qints: Pytree, R_tree: Pytree, bits: int) -> Pytree:
    """Inverse map: delta_i = 2 tau R q_i - R (paper eq. 6).

    ``qhat_new = qhat + dequantize_innovation(...)`` recovers Q_m(theta^k).
    """
    t = tau(bits)

    def _dq(q, R):
        d = 2.0 * t * R * q.astype(jnp.float32) - R
        return jnp.where(R > 0, d, jnp.zeros_like(d))

    return jax.tree.map(_dq, qints, R_tree)


def roundtrip_parts(grad: Pytree, qhat: Pytree, bits: int,
                    per_leaf: bool = False):
    """The full quantize roundtrip with every intermediate exposed:
    ``(qints, R_tree, delta, q_new, R_max, err_sq)``.  Single source of the
    composition shared by :func:`quantize_roundtrip` and the reference wire
    backend (core/wire.py) — their bit-identity contract depends on this
    being one implementation.
    """
    qints, R_tree = quantize_innovation(grad, qhat, bits, per_leaf)
    delta = dequantize_innovation(qints, R_tree, bits)
    q_new = jax.tree.map(lambda q, d: q.astype(jnp.float32) + d, qhat, delta)
    err_sq = tree_sq_norm(jax.tree.map(lambda g, qn: g.astype(jnp.float32) - qn, grad, q_new))
    R_max = jnp.max(jnp.stack(jax.tree_util.tree_leaves(R_tree)))
    return qints, R_tree, delta, q_new, R_max, err_sq


def quantize_roundtrip(grad: Pytree, qhat: Pytree, bits: int,
                       per_leaf: bool = False):
    """Quantize-and-reconstruct in one call.

    Returns ``(q_new, delta, R_max, err_sq)``:
      * ``q_new``  — Q_m(theta^k) = qhat + delta  (the new quantized gradient)
      * ``delta``  — the dequantized innovation deltaQ_m^k
      * ``R_max``  — max leaf radius (diagnostic; paper Fig. 3 decay)
      * ``err_sq`` — ||grad - q_new||_2^2  (the quantization error eps_m^k)

    Guarantee (paper Fig. 1): ||grad - q_new||_inf <= tau * R.
    """
    _, _, delta, q_new, R_max, err_sq = roundtrip_parts(grad, qhat, bits,
                                                        per_leaf)
    return q_new, delta, R_max, err_sq


# ---------------------------------------------------------------------------
# Bit-packing: the physical wire format.  b=1 packs eight codes per byte,
# b=2 four, b=4 two; b=8 is already one byte per code.  Used by the
# packed-collective wire mode and by the Pallas kernels
# (kernels/quant_pack.py mirrors this math).
# ---------------------------------------------------------------------------

PACKABLE_BITS = (1, 2, 4, 8)


def pack_codes(q: jax.Array, bits: int) -> jax.Array:
    """Pack a flat uint8 array of b-bit codes, 8/b per byte (b in
    {1,2,4,8}).

    Code i lands in byte i // (8/b) at bit offset b * (i % (8/b)) — the
    little-end-first layout shared by pack_nibbles and the Pallas kernels.
    Length must be a multiple of 8/b (pad upstream).

    Vectorized: one contiguous reshape to ``[n/cpb, cpb]`` and a broadcast
    shift-and-OR over the (static, tiny) byte-lane axis, instead of 8/b
    strided gathers over the full code vector.
    """
    assert bits in PACKABLE_BITS, bits
    cpb = 8 // bits
    if cpb == 1:
        return q.astype(jnp.uint8)
    lanes = q.astype(jnp.uint8).reshape(-1, cpb)
    acc = lanes[:, 0]
    for j in range(1, cpb):       # static, <= 3 iterations; contiguous columns
        acc = acc | (lanes[:, j] << (bits * j))
    return acc.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """Inverse of pack_codes -> flat uint8 array of b-bit codes.

    Vectorized: one broadcast shift-and-mask to ``[nbytes, cpb]`` and a
    contiguous reshape back to the flat code vector.
    """
    assert bits in PACKABLE_BITS, bits
    cpb = 8 // bits
    if cpb == 1:
        return packed.astype(jnp.uint8)
    mask = jnp.uint8((1 << bits) - 1)
    shifts = jnp.arange(cpb, dtype=jnp.uint8) * bits
    lanes = (packed.reshape(-1, 1) >> shifts[None, :]) & mask
    return lanes.reshape(-1).astype(jnp.uint8)


def pack_nibbles(q: jax.Array) -> jax.Array:
    """Pack a flat uint8 array of 4-bit codes, two per byte.

    Length must be even (pad upstream).
    """
    return pack_codes(q, 4)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """Inverse of pack_nibbles -> flat uint8 array of 4-bit codes."""
    return unpack_codes(packed, 4)


def upload_bits(p: int, bits, *, n_radii: int = 1, bit_sidecar: bool = False):
    """Wire cost of one upload: ``32 * n_radii`` sidecar bits for the
    radius/radii, b bits per coordinate, plus (adaptive LAQ only) one byte
    announcing the chosen bit-width b.  ``bits`` may be a traced value in
    the adaptive path; with the defaults and a python int this reduces to the
    paper's ``32 + b p``."""
    return 32 * n_radii + (8 if bit_sidecar else 0) + bits * p


def dense_bits(p: int) -> int:
    """Uncompressed float32 upload cost (GD / LAG per-round cost)."""
    return 32 * p


def index_bits(p: int) -> int:
    """Bits to address one of ``p`` coordinates: ``ceil(log2 p)``."""
    return max(1, int(math.ceil(math.log2(max(p, 2)))))


def sparse_upload_bits(p: int, k: int, bits, *, n_radii: int = 1):
    """Wire cost of one sparse upload (the EF-LAQ compressor pipeline):
    ``32 * n_radii`` sidecar bits for the radius/radii plus, per surviving
    coordinate, its ``ceil(log2 p)``-bit index and its b-bit code.  ``k``
    is static configuration (``StrategyConfig.compressor_k``), so no count
    sidecar is needed — both ends know the payload length."""
    return 32 * n_radii + k * (bits + index_bits(p))
