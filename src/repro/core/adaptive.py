"""Adaptive bit-width selection (A-LAQ; Mahmoudi et al. 2022).

LAQ fixes the quantization width ``b`` for the whole run, but the innovation
radius ``R_m^k`` decays as training converges (paper Fig. 3): a fixed grid
wastes wire bits late and starves precision early.  This module picks a
per-worker, per-round width ``b_m^k`` from a small grid (default {2, 4, 8}):

* ``kind="radius"`` — radius-decay schedule: thresholds on the current
  innovation radius; large R (early training / high innovation) buys more
  bits, small R fewer.  Stateless given R.

Thresholds come in two flavors (``threshold_mode``):

* ``"abs"`` — thresholds are absolute radii.  Simple, but per-workload: the
  radius scale of a logistic-regression gradient and of an LM gradient
  differ by orders of magnitude, so every problem needs its own tuple.
* ``"rel"`` — **scale-free**: thresholds are *fractions of an anchor
  radius* tracked per worker in ``CommState.R_anchor``.  The anchor is a
  decaying peak envelope ``A^k = max(R_m^k, anchor_decay * A^{k-1})`` with
  ``A^0 = 0``: at the dense bootstrap round it snaps to that round's radius,
  and as the innovation radius decays (paper Fig. 3) ``R/A`` falls through
  the fractions and the width steps down — the same trajectory the absolute
  thresholds had to be hand-tuned to produce, with no per-workload
  constants.  Because ``R <= A`` by construction, the single comparison
  ``R > th * A`` gives fractions a two-sided meaning with no special cases:

  - fractions < 1 partition the post-bootstrap decay as usual;
  - fractions >= 1 mark grid levels *unreachable after the bootstrap*, and
    thereby choose the bootstrap width itself: at the bootstrap round
    ``R == A``, so exactly the fractions < 1 are exceeded and the selected
    level is ``grid[#{th < 1}]``.  E.g. on ``grid=(2, 4, 8)``,
    ``(0.05, 0.5)`` bootstraps at 8 bits and uses all three levels, while
    ``(0.5, 2.0)`` bootstraps at 4 bits and never buys 8 — a cheap
    schedule for radius trajectories that collapse within a few rounds.

  ``anchor_decay = 1.0`` keeps the running max (a pure bootstrap-round
  anchor under monotone decay); ``anchor_decay < 1`` makes the envelope
  track the radius *decay rate*, so after a collapse-then-plateau the
  anchor closes back onto the plateau and the width re-opens — the knob
  for non-stationary radius trajectories.
* ``kind="budget"`` — A-LAQ-style budgeted controller: a cumulative
  per-worker wire-bit budget ``total_bits`` spread over ``horizon`` rounds;
  each round the worker takes the radius-preferred width, then steps down the
  grid until the upload fits its remaining allowance (always at least the
  smallest width, so progress never stalls).
* ``kind="constant"`` — degenerate schedule; the strategy layer routes it to
  the fixed-bit code path, so it is bit-exact with classic LAQ by
  construction.

Everything here is traceable: the chosen width is a traced scalar, and the
dynamic quantizer evaluates the (static, tiny) grid of widths and selects by
mask, so it lives happily under vmap/scan/shard_map.  The dequantization
arithmetic is kept expression-for-expression identical to
:mod:`repro.core.quantize` so a pinned dynamic selection reproduces the fixed
path bit-for-bit (property-tested in tests/test_adaptive.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .quantize import (innovation, quantize_codes, tau, tree_sq_norm,
                       upload_bits)

Pytree = object


class BitSchedule(NamedTuple):
    kind: str = "constant"          # constant | radius | budget
    bits: int = 4                   # constant-mode width
    grid: tuple = (2, 4, 8)         # ascending candidate widths
    # radius schedule: len(grid)-1 ascending thresholds on R_m^k;
    # R <= thresholds[0] -> grid[0], ..., R > thresholds[-1] -> grid[-1]
    thresholds: tuple = (0.05, 0.5)
    # "abs": thresholds are absolute radii (per-workload tuning);
    # "rel": thresholds are fractions of the per-worker anchor radius
    # (bootstrap-round peak envelope; see module docstring) — scale-free
    threshold_mode: str = "abs"
    anchor_decay: float = 1.0       # rel only: peak-envelope decay per round
    # budget controller: total per-worker wire bits spread over horizon rounds
    total_bits: float = 0.0
    horizon: int = 0

    @property
    def adaptive(self) -> bool:
        return self.kind != "constant"

    def validate(self):
        assert self.kind in ("constant", "radius", "budget"), self.kind
        assert tuple(sorted(self.grid)) == tuple(self.grid), self.grid
        assert all(b in (2, 4, 8) for b in self.grid), self.grid
        assert self.threshold_mode in ("abs", "rel"), self.threshold_mode
        if self.adaptive:
            assert len(self.thresholds) == len(self.grid) - 1, self
            assert tuple(sorted(self.thresholds)) == tuple(self.thresholds), self
        if self.threshold_mode == "rel":
            assert all(t > 0.0 for t in self.thresholds), \
                f"rel thresholds are fractions of the anchor radius: {self}"
            assert 0.0 < self.anchor_decay <= 1.0, self.anchor_decay
        if self.kind == "budget":
            assert self.total_bits > 0 and self.horizon > 0, self
        return self


class EtaSchedule(NamedTuple):
    """Stepsize schedule ``alpha_k = eta_at(schedule, alpha0, k)``.

    The stochastic lazy methods plateau at a variance floor proportional to
    ``alpha * sigma^2``; a decreasing stepsize drives that floor to zero
    (LASG Thm. 4 carries the standard Robbins-Monro conditions).  Three
    kinds:

    * ``"constant"`` — ``alpha_k = alpha0`` (the default; bit-exact with
      the historical fixed-stepsize paths).
    * ``"inv_t"``    — ``alpha_k = alpha0 * t0 / (t0 + k)``: the classic
      1/t decay; ``t0`` delays the decay so early rounds keep a useful
      stepsize (``t0 = 100`` halves alpha at k = 100).
    * ``"halving"``  — stagewise: ``alpha_k = alpha0 * 0.5^(k // halve_every)``
      — the constant-within-stage schedule the variance-reduced analyses
      favor (each stage converges to its floor, then the floor is halved).

    The schedule feeds BOTH the parameter update and the skip criterion:
    eq. 7a's history term carries ``1/(alpha^2 M^2)``, so the per-round
    alpha must be the one the server actually applies or the threshold is
    inconsistent with the realized parameter motion.
    """
    kind: str = "constant"          # constant | inv_t | halving
    t0: float = 100.0               # inv_t: decay timescale in rounds
    halve_every: int = 100          # halving: stage length in rounds

    @property
    def scheduled(self) -> bool:
        return self.kind != "constant"

    def validate(self):
        assert self.kind in ("constant", "inv_t", "halving"), self.kind
        if self.kind == "inv_t":
            assert self.t0 > 0, self
        if self.kind == "halving":
            assert self.halve_every >= 1, self
        return self


def eta_at(schedule: EtaSchedule, alpha0, step):
    """Traced per-round stepsize (``step`` is the round index, 0-based)."""
    schedule.validate()
    if schedule.kind == "constant":
        # NOT jnp.asarray(alpha0): the constant path must stay a python
        # float so downstream `alpha**2` arithmetic is bit-identical with
        # pre-schedule code (regression-locked by the wire-backend tests)
        return alpha0
    k = jnp.asarray(step, jnp.float32)
    if schedule.kind == "inv_t":
        return alpha0 * schedule.t0 / (schedule.t0 + k)
    return alpha0 * 0.5 ** jnp.floor(k / schedule.halve_every)


def grid_costs(schedule: BitSchedule, p: int, n_radii: int = 1) -> jnp.ndarray:
    """Per-upload wire cost of each grid width (codes + R/b sidecars)."""
    return jnp.asarray([upload_bits(p, b, n_radii=n_radii, bit_sidecar=True)
                        for b in schedule.grid], jnp.float32)


def select_bits(schedule: BitSchedule, R, bits_spent, step, p: int,
                n_radii: int = 1, R_anchor=None):
    """Pick this worker's width for the round.

    Args: ``R`` — current innovation radius (scalar); ``bits_spent`` — this
    worker's cumulative wire bits; ``step`` — round index; ``p`` — gradient
    dimension; ``R_anchor`` — the worker's anchor radius (``"rel"``
    threshold mode; ``None``/0 means unanchored yet).  Returns ``(b_sel,
    onehot, anchor_new)`` where ``b_sel`` is the chosen width as a traced
    f32 scalar, ``onehot`` its indicator over the grid, and ``anchor_new``
    the updated anchor (pass-through in ``"abs"`` mode).

    Budget invariant (property-tested): whenever the burst-extended allowance
    covers at least the smallest width, the chosen upload fits it; otherwise
    the smallest width is chosen.  The allowance is pro-rata plus a one-upload
    *burst* (the cost of the widest grid entry) — without the burst the dense
    bootstrap round would be starved by an empty round-0 allowance; with it,
    cumulative spend provably stays within ``rate * k + cost(max(grid))``.
    """
    schedule.validate()   # malformed schedules (e.g. stale thresholds after a
    # grid change) would otherwise select an all-zero onehot -> b_sel = 0 and
    # silently corrupt training; validate() turns that into a trace-time error
    G = len(schedule.grid)
    th = jnp.asarray(schedule.thresholds, jnp.float32)
    anchor_prev = (jnp.zeros((), jnp.float32) if R_anchor is None
                   else jnp.asarray(R_anchor, jnp.float32))
    if schedule.threshold_mode == "rel":
        # decaying peak envelope; at the bootstrap round (anchor 0) it snaps
        # to R itself, so R exceeds every fractional threshold -> max width
        anchor_new = jnp.maximum(jnp.asarray(R, jnp.float32),
                                 schedule.anchor_decay * anchor_prev)
        th = th * anchor_new
    else:
        anchor_new = anchor_prev
    idx = jnp.sum((R > th).astype(jnp.int32))           # radius preference
    if schedule.kind == "budget":
        costs = grid_costs(schedule, p, n_radii)
        rate = float(schedule.total_bits) / float(schedule.horizon)
        allowance = rate * (jnp.asarray(step, jnp.float32) + 1.0) \
            + costs[-1] - jnp.asarray(bits_spent, jnp.float32)
        fits = costs <= allowance
        idx_budget = jnp.max(jnp.where(fits, jnp.arange(G), 0))
        idx = jnp.minimum(idx, idx_budget)
    onehot = jax.nn.one_hot(idx, G, dtype=jnp.float32)
    b_sel = jnp.sum(onehot * jnp.asarray(schedule.grid, jnp.float32))
    return b_sel, onehot, anchor_new


# ---------------------------------------------------------------------------
# Dynamic-width quantization: evaluate the static grid, select by mask.
# Shares the radius/codes math with core/quantize.py (bit-exactness by
# construction: `innovation` and `quantize_codes` are the same functions the
# fixed path uses).
# ---------------------------------------------------------------------------

def quantize_dynamic(diff: Pytree, R_tree: Pytree, grid, onehot) -> Pytree:
    """Codes for the selected width: grid evaluated statically, masked select."""
    def leaf(d, R):
        out = None
        for i, b in enumerate(grid):
            q = quantize_codes(d, R, b)
            out = q if out is None else jnp.where(onehot[i] > 0, q, out)
        return out
    return jax.tree.map(leaf, diff, R_tree)


def tau_of_selection(grid, onehot):
    """tau(b_sel) selected from precomputed per-grid constants (bit-exact
    with the fixed path: x2 scaling commutes with the f64->f32 rounding)."""
    taus = jnp.asarray([tau(b) for b in grid], jnp.float32)
    return jnp.sum(taus * onehot)


def tau_of_width(grid, b):
    """Per-worker tau lookup from an exchanged width sidecar ``b`` (any
    shape). Table lookup, not ``1/(2**b - 1)`` arithmetic, so the wire decode
    matches :func:`tau_of_selection` bit-for-bit."""
    grid_arr = jnp.asarray(grid, jnp.float32)
    taus = jnp.asarray([tau(g) for g in grid], jnp.float32)
    return jnp.sum(jnp.where(grid_arr == b[..., None], taus, 0.0), axis=-1)


def dequantize_dynamic(codes: Pytree, R_tree: Pytree, t_sel) -> Pytree:
    """delta_i = 2 tau(b_sel) R q_i - R (paper eq. 6 with the selected b)."""
    def leaf(q, R):
        d = 2.0 * t_sel * R * q.astype(jnp.float32) - R
        return jnp.where(R > 0, d, jnp.zeros_like(d))
    return jax.tree.map(leaf, codes, R_tree)


def adaptive_roundtrip(grad: Pytree, qhat: Pytree, grid, onehot,
                       per_leaf: bool = False):
    """Dynamic-width analogue of :func:`repro.core.quantize.quantize_roundtrip`.

    Returns ``(q_new, delta, R_max, err_sq)`` for the width encoded in
    ``onehot``.
    """
    diff, R_tree, R_max = innovation(grad, qhat, per_leaf)
    codes = quantize_dynamic(diff, R_tree, grid, onehot)
    delta = dequantize_dynamic(codes, R_tree, tau_of_selection(grid, onehot))
    q_new = jax.tree.map(lambda q, d: q.astype(jnp.float32) + d, qhat, delta)
    err_sq = tree_sq_norm(jax.tree.map(
        lambda g, qn: g.astype(jnp.float32) - qn, grad, q_new))
    return q_new, delta, R_max, err_sq
