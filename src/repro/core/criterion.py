"""Lazy-aggregation skip criterion (paper eq. 7a / 7b).

Worker m skips its upload at iteration k iff

    ||Q_m(theta_hat^{k-1}) - Q_m(theta^k)||^2
        <= 1/(alpha^2 M^2) * sum_d xi_d ||theta^{k+1-d} - theta^{k-d}||^2
           + 3 (||eps_m^k||^2 + ||eps_hat_m^{k-1}||^2)                 (7a)
    and  t_m <= t_bar                                                  (7b)

where the theta-difference history is maintained by the server (here: by the
replicated SPMD state), eps_m^k is the current quantization error and
eps_hat_m^{k-1} the error stored at the worker's last upload.

The right-hand side (the xi-weighted history term plus the quantization-error
slack) is shared threshold machinery: the variance-aware stochastic rules in
:mod:`repro.core.lazy_rules` (LASG-WK / LASG-PS) reuse
:func:`rhs_threshold` verbatim and swap only the left-hand side.  Symbol
mapping to the paper: ``docs/paper-map.md``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class CriterionConfig(NamedTuple):
    D: int = 10                 # history depth
    xi: float = 0.8 / 10        # xi_d (constant across d, paper Sec. 4)
    t_bar: int = 100            # staleness bound
    include_quant_error: bool = True  # the 3(eps^2 + eps_hat^2) slack term


def history_threshold(theta_diff_hist: jnp.ndarray, alpha, M: int,
                      cfg: CriterionConfig):
    """The xi-weighted parameter-motion term of (7a):
    ``1/(alpha^2 M^2) * sum_d xi_d ||theta^{k+1-d} - theta^{k-d}||^2`` with
    ``theta_diff_hist[d-1] = ||theta^{k+1-d}-theta^{k-d}||^2``."""
    xi = jnp.full((cfg.D,), cfg.xi, dtype=jnp.float32)
    return jnp.dot(xi, theta_diff_hist) / (alpha**2 * M**2)


def rhs_threshold(theta_diff_hist: jnp.ndarray, alpha, M: int,
                  eps_sq, eps_hat_sq, cfg: CriterionConfig):
    """Right-hand side of (7a): history term + quantization-error slack."""
    hist_term = history_threshold(theta_diff_hist, alpha, M, cfg)
    err_term = 3.0 * (eps_sq + eps_hat_sq) if cfg.include_quant_error else 0.0
    return hist_term + err_term


def should_skip(innovation_sq, theta_diff_hist, alpha, M: int,
                eps_sq, eps_hat_sq, clock, cfg: CriterionConfig):
    """Boolean skip decision for one worker (vmap over workers upstream)."""
    ok_7a = innovation_sq <= rhs_threshold(theta_diff_hist, alpha, M,
                                           eps_sq, eps_hat_sq, cfg)
    ok_7b = clock < cfg.t_bar
    return jnp.logical_and(ok_7a, ok_7b)


def push_history(theta_diff_hist: jnp.ndarray, new_sq) -> jnp.ndarray:
    """Ring-push the newest ||theta^{k+1} - theta^k||^2 (index 0 = most recent)."""
    return jnp.concatenate([jnp.reshape(new_sq, (1,)).astype(theta_diff_hist.dtype),
                            theta_diff_hist[:-1]])
