"""Communication strategies: GD / QGD / LAG / LAQ (+ stochastic variants).

All four gradient-based methods of the paper are one state machine with two
switches:

    quantize?  lazy-skip?
GD     no         no        theta^{k+1} = theta^k - alpha * sum_m grad_m
QGD    yes        no        paper eq. (3)
LAG    no         yes       Chen et al. 2018 (paper ref [6])
LAQ    yes        yes       paper eq. (4) + criterion (7)

The *server* aggregate  ``agg^k = agg^{k-1} + sum_{m in M^k} deltaQ_m^k``  is
maintained as replicated SPMD state.  Stochastic variants (SGD/SLAQ) use the
same machinery on minibatch gradients.

Two execution modes share the same per-worker math (``worker_update``):

* **simulated** — a leading worker axis ``W`` on the gradient pytree, vmapped.
  Used by the paper-reproduction benchmarks (M=10 workers on one device).
* **sharded** — called per-shard inside ``jax.shard_map`` where the worker
  axis is a mesh axis; the caller supplies the psum. See ``launch/train.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .criterion import CriterionConfig, push_history, should_skip
from .quantize import (dense_bits, quantize_roundtrip, tree_size, tree_sq_norm,
                       upload_bits)

Pytree = object

KINDS = ("gd", "qgd", "lag", "laq")


class StrategyConfig(NamedTuple):
    kind: str = "laq"               # one of KINDS
    bits: int = 4                   # quantization bits per coordinate
    criterion: CriterionConfig = CriterionConfig()
    per_leaf_radius: bool = False   # paper: one global R; True = bucketed
    first_round_upload: bool = True  # init clocks at t_bar: round 1 is dense
    state_bf16: bool = False        # store qhat/server_agg in bf16 (beyond-
                                    # paper memory opt; grid values tolerate it
                                    # and the innovation loop self-corrects)
    # wire mode is a launch-layer concern ("float" psum vs "packed" all_gather);
    # the algorithmic state machine is identical for both.

    @property
    def quantized(self) -> bool:
        return self.kind in ("qgd", "laq")

    @property
    def lazy(self) -> bool:
        return self.kind in ("lag", "laq")


class CommState(NamedTuple):
    """Replicated/sharded LAQ state.

    ``qhat``/``eps_hat_sq``/``clocks`` carry a leading worker dim W in
    simulated mode; in sharded mode that dim is the mesh worker axis and each
    shard holds its own slice (no leading dim).
    """
    qhat: Pytree            # last uploaded quantized gradient  Q_m(theta_hat)
    server_agg: Pytree      # server aggregate  agg^{k-1}
    eps_hat_sq: jax.Array   # ||eps_hat_m||^2 at last upload
    clocks: jax.Array       # t_m
    theta_hist: jax.Array   # [D]  ||theta^{k+1-d} - theta^{k-d}||^2 ring
    total_bits: jax.Array   # float64-ish accumulator (float32 ok for tests)
    total_uploads: jax.Array
    step: jax.Array


class RoundMetrics(NamedTuple):
    uploads: jax.Array      # |M^k| this round
    bits: jax.Array         # wire bits this round
    mean_skip: jax.Array    # fraction of workers skipping
    radius_max: jax.Array   # max_m R_m^k (0 for unquantized)


def init_comm_state(grad_template: Pytree, n_workers: int,
                    cfg: StrategyConfig, *, worker_dim: bool = True) -> CommState:
    """Zero-initialized state. ``grad_template`` gives shapes/dtypes of one
    worker's gradient pytree (no worker dim)."""
    sdtype = jnp.bfloat16 if cfg.state_bf16 else jnp.float32

    def zeros_like_s(l):
        shape = (n_workers,) + l.shape if worker_dim else l.shape
        return jnp.zeros(shape, sdtype)

    wshape = (n_workers,) if worker_dim else ()
    # clocks start at t_bar when first_round_upload: criterion (7b) then
    # forces a dense first round, bootstrapping qhat / the server aggregate.
    clock0 = cfg.criterion.t_bar if (cfg.lazy and cfg.first_round_upload) else 0
    return CommState(
        qhat=jax.tree.map(zeros_like_s, grad_template),
        server_agg=jax.tree.map(lambda l: jnp.zeros(l.shape, sdtype), grad_template),
        eps_hat_sq=jnp.zeros(wshape, jnp.float32),
        clocks=jnp.full(wshape, clock0, jnp.int32),
        theta_hist=jnp.zeros((cfg.criterion.D,), jnp.float32),
        total_bits=jnp.zeros((), jnp.float32),
        total_uploads=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Per-worker update: the heart of LAQ.  Pure; no collectives.
# ---------------------------------------------------------------------------

def worker_update(grad_m: Pytree, qhat_m: Pytree, eps_hat_sq_m, clock_m,
                  theta_hist, alpha, n_workers: int, cfg: StrategyConfig):
    """One worker's quantize + skip decision.

    Returns ``(delta_masked, qhat_new, eps_hat_sq_new, clock_new, uploaded,
    bits_m, R_m)`` where ``delta_masked`` is this worker's contribution to the
    server-aggregate refinement (zero if the upload is skipped).
    """
    p = tree_size(grad_m)
    if cfg.quantized:
        q_new, delta, R, err_sq = quantize_roundtrip(grad_m, qhat_m, cfg.bits,
                                                     cfg.per_leaf_radius)
        n_sidecars = (len(jax.tree_util.tree_leaves(grad_m))
                      if cfg.per_leaf_radius else 1)
        bits_if_upload = float(upload_bits(p, cfg.bits)) + 32.0 * (n_sidecars - 1)
    else:
        q_new = jax.tree.map(lambda g: g.astype(jnp.float32), grad_m)
        delta = jax.tree.map(lambda g, q: g - q, q_new, qhat_m)
        R = jnp.zeros((), jnp.float32)
        err_sq = jnp.zeros((), jnp.float32)
        bits_if_upload = float(dense_bits(p))

    innovation_sq = tree_sq_norm(delta)

    if cfg.lazy:
        skip = should_skip(innovation_sq, theta_hist, alpha, n_workers,
                           err_sq, eps_hat_sq_m, clock_m, cfg.criterion)
    else:
        skip = jnp.zeros((), bool)
    uploaded = jnp.logical_not(skip)

    fup = uploaded.astype(jnp.float32)
    delta_masked = jax.tree.map(lambda d: d * fup, delta)
    qhat_new = jax.tree.map(lambda qn, qh: jnp.where(uploaded, qn.astype(qh.dtype), qh),
                            q_new, qhat_m)
    eps_hat_sq_new = jnp.where(uploaded, err_sq, eps_hat_sq_m)
    clock_new = jnp.where(uploaded, 0, clock_m + 1).astype(jnp.int32)
    bits_m = fup * bits_if_upload
    return delta_masked, qhat_new, eps_hat_sq_new, clock_new, uploaded, bits_m, R


# ---------------------------------------------------------------------------
# Simulated cluster mode (vmap over a leading worker axis).
# ---------------------------------------------------------------------------

def aggregate(state: CommState, grads: Pytree, alpha, cfg: StrategyConfig):
    """Aggregate per-worker gradients (leading dim W) into the LAQ gradient.

    Returns ``(agg_grad, new_state, metrics)``.  The caller applies
    ``theta <- theta - alpha * agg_grad`` (or feeds agg_grad to an optimizer)
    and then calls :func:`finalize_step` with the realized parameter change.
    """
    n_workers = jax.tree_util.tree_leaves(state.clocks)[0].shape[0] \
        if hasattr(state.clocks, "shape") and state.clocks.ndim else 1
    n_workers = state.clocks.shape[0]

    upd = functools.partial(worker_update, theta_hist=state.theta_hist,
                            alpha=alpha, n_workers=n_workers, cfg=cfg)
    (delta_masked, qhat_new, eps_hat_sq_new, clock_new,
     uploaded, bits_m, R_m) = jax.vmap(upd)(grads, state.qhat,
                                            state.eps_hat_sq, state.clocks)

    # Server recursion: agg^k = agg^{k-1} + sum_m deltaQ_m.
    agg = jax.tree.map(lambda a, d: a + jnp.sum(d, axis=0),
                       state.server_agg, delta_masked)

    uploads = jnp.sum(uploaded.astype(jnp.int32))
    bits = jnp.sum(bits_m)
    metrics = RoundMetrics(uploads=uploads, bits=bits,
                           mean_skip=1.0 - uploads / n_workers,
                           radius_max=jnp.max(R_m))
    new_state = state._replace(
        qhat=qhat_new, server_agg=agg, eps_hat_sq=eps_hat_sq_new,
        clocks=clock_new,
        total_bits=state.total_bits + bits,
        total_uploads=state.total_uploads + uploads,
        step=state.step + 1,
    )
    return agg, new_state, metrics


def finalize_step(state: CommState, theta_diff_sq) -> CommState:
    """Push ||theta^{k+1}-theta^k||^2 into the criterion's history ring."""
    return state._replace(theta_hist=push_history(state.theta_hist, theta_diff_sq))
