"""Communication strategies: GD / QGD / LAG / LAQ (+ stochastic variants).

All four gradient-based methods of the paper are one state machine with two
switches:

    quantize?  lazy-skip?
GD     no         no        theta^{k+1} = theta^k - alpha * sum_m grad_m
QGD    yes        no        paper eq. (3)
LAG    no         yes       Chen et al. 2018 (paper ref [6])
LAQ    yes        yes       paper eq. (4) + criterion (7)

The *server* aggregate  ``agg^k = agg^{k-1} + sum_{m in M^k} deltaQ_m^k``  is
maintained as replicated SPMD state.  Stochastic variants (SGD/SLAQ) use the
same machinery on minibatch gradients; for those, ``StrategyConfig.lazy_rule``
selects the skip criterion — the paper's eq. 7a, or the variance-aware
LASG-WK / LASG-PS rules of :mod:`repro.core.lazy_rules` whose per-worker
estimator state rides in ``CommState.lazy``.

Two execution modes share the same per-worker math (``worker_update``):

* **simulated** — a leading worker axis ``W`` on the gradient pytree, vmapped.
  Used by the paper-reproduction benchmarks (M=10 workers on one device).
* **sharded** — called per-shard inside ``jax.shard_map`` where the worker
  axis is a mesh axis; the caller supplies the psum. See ``launch/train.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adaptive import BitSchedule, EtaSchedule, select_bits
from .compressors import (COMPRESSORS, ErrorState, compressor_keys,
                          empty_error_state, init_error_state, static_k)
from .criterion import CriterionConfig, push_history, should_skip
from .defense import (AGGREGATORS, DefenseConfig, DefenseState, defense_step,
                      empty_defense_state, init_defense_state,
                      robust_aggregate)
from .faults import FaultConfig, flip_wire_codes
from .lazy_rules import (LAZY_RULES, LasgConfig, LazyState, commit_upload,
                         empty_lazy_state, init_lazy_state, lazy_rule_step)
from .quantize import (dense_bits, sparse_upload_bits, tree_size,
                       tree_sq_norm, upload_bits)
from .wire import get_backend, sparse_roundtrip

Pytree = object

KINDS = ("gd", "qgd", "lag", "laq")


class StrategyConfig(NamedTuple):
    kind: str = "laq"               # one of KINDS
    bits: int = 4                   # quantization bits per coordinate
    criterion: CriterionConfig = CriterionConfig()
    per_leaf_radius: bool = False   # paper: one global R; True = bucketed
    first_round_upload: bool = True  # init clocks at t_bar: round 1 is dense
    state_bf16: bool = False        # store qhat/server_agg in bf16 (beyond-
                                    # paper memory opt; grid values tolerate it
                                    # and the innovation loop self-corrects)
    bit_schedule: Optional[BitSchedule] = None  # None/"constant" -> fixed
                                    # bits; adaptive kinds pick b_m^k per
                                    # worker per round (core/adaptive.py)
    wire_backend: str = "reference"  # quantize-pipeline implementation
                                    # (core/wire.py): "reference" jnp vs
                                    # "fused" two-pass Pallas/blocked-jnp;
                                    # bit-identical wire content either way
    lazy_rule: str = "laq7a"        # skip criterion for lazy kinds
                                    # (core/lazy_rules.py): "laq7a" paper
                                    # eq. 7a; "lasg_wk" variance-corrected
                                    # worker rule; "lasg_wk2" same-sample
                                    # noise-free rule (2nd backprop);
                                    # "lasg_ps" server-side parameter-drift
                                    # rule
    lasg: LasgConfig = LasgConfig()  # constants of the LASG rules
    grad_mode: str = "sgd"          # stochastic local-gradient estimator:
                                    # "sgd" plain minibatch; "svrg"
                                    # variance-reduced (periodic per-worker
                                    # full-gradient anchor in CommState.svrg,
                                    # corrected minibatch gradients fed to
                                    # the lazy rules AND the quantizer).
                                    # Deterministic runs ignore it.
    svrg_period: int = 20           # rounds between svrg anchor refreshes
    eta_schedule: EtaSchedule = EtaSchedule()  # per-round stepsize alpha_k
                                    # (core/adaptive.py): constant / inv_t /
                                    # halving; feeds both the update and the
                                    # criterion's 1/(alpha^2 M^2) term
    participation: str = "full"     # which workers the server reaches each
                                    # round (core/engine.py): "full" |
                                    # "bernoulli" / "fixed_k" client sampling
                                    # | "markov" bursty on/off churn
                                    # | "delay" bounded-staleness async
                                    # ("markov"/"delay": simulated engine only)
    participation_p: float = 1.0    # bernoulli keep-probability / fixed_k
                                    # cohort fraction (k = round(p * W))
    max_delay: int = 0              # "delay": staleness bound D; worker m
                                    # computes at theta^{k - (m mod (D+1))}
    participation_seed: int = 0     # seed of the availability stream
                                    # (independent of batch/compressor RNG)
    compressor: str = "none"        # sparsifying compressor stage
                                    # (core/compressors.py): "none" dense
                                    # quantization (the paper); "topk" /
                                    # "randk" keep k of p innovation
                                    # coordinates before the b-bit grid —
                                    # wire cost 64 + k (b + ceil(log2 p))
    compressor_k: float = 0.25      # kept fraction: k = round(frac * p),
                                    # static under jit
    error_feedback: bool = False    # EF-LAQ: carry the compression residual
                                    # e_m in CommState.error and add it back
                                    # before the next compress (committed on
                                    # upload only, frozen over skips)
    ef_damping: float = 0.5         # injection weight eta on the carried
                                    # residual: g_eff = g + eta e.  eta = 1
                                    # (textbook EF) double-counts the
                                    # innovation reference's implicit error
                                    # carry — loop gain (1 + eta) rho — and
                                    # diverges whenever the compressor's
                                    # contraction rho >= 1/2 (rand-k, 1-bit
                                    # grids); see docs/compressors.md
    compressor_seed: int = 0        # seed of the randk support stream
                                    # (independent of batch / participation)
    markov_sojourn: float = 8.0     # "markov" participation: mean ON-streak
                                    # length in rounds; 1/(1-p) reduces the
                                    # chain to i.i.d. bernoulli(p)
    faults: FaultConfig = FaultConfig()  # fault injection (core/faults.py):
                                    # payload corruption / wire bit-flips /
                                    # crash-restart; all-off by default
    defense: DefenseConfig = DefenseConfig()  # server-side upload validation
                                    # + norm-clipping (core/defense.py); a
                                    # rejected upload is masked exactly like
                                    # a lazy skip, bits counted honestly
    aggregator: str = "sum"         # combination of committed deltas:
                                    # "sum" (the paper's recursion) |
                                    # "trimmed_mean" / "median" coordinate-
                                    # wise robust aggregation (simulated
                                    # engine only; see docs/robustness.md
                                    # for the recursion-drift caveat)
    trim_frac: float = 0.1          # "trimmed_mean": fraction of workers
                                    # trimmed at EACH end (t = floor(f * W),
                                    # min 1)
    # wire mode is a launch-layer concern ("float" psum vs "packed" all_gather);
    # the algorithmic state machine is identical for both.

    @property
    def quantized(self) -> bool:
        return self.kind in ("qgd", "laq")

    @property
    def variance_reduced(self) -> bool:
        return self.grad_mode == "svrg"

    @property
    def lazy(self) -> bool:
        return self.kind in ("lag", "laq")

    @property
    def adaptive(self) -> bool:
        return (self.quantized and self.bit_schedule is not None
                and self.bit_schedule.adaptive)

    @property
    def compressed(self) -> bool:
        return self.compressor != "none"

    @property
    def effective_bits(self) -> int:
        """Static width of the fixed-bit path (a constant schedule routes
        here so it is bit-exact with classic fixed-bit LAQ)."""
        if self.bit_schedule is not None and not self.bit_schedule.adaptive:
            return self.bit_schedule.bits
        return self.bits


class SvrgState(NamedTuple):
    """Per-worker SVRG anchor (``StrategyConfig.grad_mode="svrg"``).

    ``theta_anchor`` is the iterate at the worker's last anchor refresh and
    ``mu_anchor`` its full *local* gradient there; between refreshes the
    runner feeds the corrected minibatch gradient

        g_vr = (n/B) (g(theta; xi) - g(theta_anchor; xi)) + mu_anchor

    to the lazy rules and the quantizer.  Both fields are ``None`` unless
    the strategy is variance-reduced (pytree discipline mirrors
    :class:`~repro.core.lazy_rules.LazyState`: rule-gated fields simply
    vanish from the flattened state).  Leading worker dim in simulated
    mode, one slice per shard in sharded mode — exactly like ``qhat``.
    The refresh itself lives in the engine stages (it needs the loss
    closure and, in simulated mode, the worker's full local data):
    ``apply_svrg_exact`` / ``apply_svrg_streaming`` in ``core/engine.py``.
    """
    theta_anchor: Optional[Pytree]
    mu_anchor: Optional[Pytree]


def init_svrg_state(grad_mode: str, grad_template: Pytree, n_workers: int,
                    *, worker_dim: bool = True) -> SvrgState:
    """Anchor snapshot of the template values (the initial iterate) and a
    zero ``mu``; the runner's round-0 refresh overwrites both."""
    assert grad_mode in ("sgd", "svrg"), grad_mode
    if grad_mode != "svrg":
        return SvrgState(None, None)
    wshape = (n_workers,) if worker_dim else ()

    def snapshot_w(l):
        return jnp.broadcast_to(jnp.asarray(l, jnp.float32), wshape + l.shape)

    return SvrgState(
        theta_anchor=jax.tree.map(snapshot_w, grad_template),
        mu_anchor=jax.tree.map(
            lambda l: jnp.zeros(wshape + l.shape, jnp.float32),
            grad_template))


class CommState(NamedTuple):
    """Replicated/sharded LAQ state.

    ``qhat``/``eps_hat_sq``/``clocks`` carry a leading worker dim W in
    simulated mode; in sharded mode that dim is the mesh worker axis and each
    shard holds its own slice (no leading dim).
    """
    qhat: Pytree            # last uploaded quantized gradient  Q_m(theta_hat)
    server_agg: Pytree      # server aggregate  agg^{k-1}
    eps_hat_sq: jax.Array   # ||eps_hat_m||^2 at last upload
    clocks: jax.Array       # t_m
    bits_spent: jax.Array   # [W] cumulative wire bits per worker (drives the
                            # adaptive budget controller; diagnostics otherwise)
    theta_hist: jax.Array   # [D]  ||theta^{k+1-d} - theta^{k-d}||^2 ring
    total_bits: jax.Array   # float64-ish accumulator (float32 ok for tests)
    total_uploads: jax.Array
    step: jax.Array
    lazy: LazyState         # per-worker LASG estimator state (variance /
                            # smoothness EMAs; pytree fields None for laq7a)
    R_anchor: jax.Array     # [W] anchor radius of the scale-free ("rel")
                            # adaptive thresholds (0 until the bootstrap
                            # round observes the first nonzero R_m)
    svrg: SvrgState         # per-worker SVRG anchor (theta_anchor /
                            # mu_anchor; fields None unless grad_mode="svrg")
    error: ErrorState = ErrorState(None)  # per-worker EF residual e_m
                            # (core/compressors.py; None unless
                            # error_feedback — same gating as lazy/svrg)
    defense: DefenseState = DefenseState(None, None, None)  # per-worker
                            # server-side validation state + reject ledger
                            # (core/defense.py; None unless
                            # DefenseConfig.active — same gating as
                            # lazy/svrg/error)


class RoundMetrics(NamedTuple):
    uploads: jax.Array      # |M^k| this round (transmissions, incl. rejected)
    bits: jax.Array         # wire bits this round
    mean_skip: jax.Array    # fraction of workers skipping
    radius_max: jax.Array   # max_m R_m^k (0 for unquantized)
    mean_bits: jax.Array    # mean selected width over uploading workers
                            # (== the static width for fixed-bit runs)
    rejections: jax.Array = jnp.zeros((), jnp.int32)  # transmissions the
                            # server refused to commit (defense validation)


def init_comm_state(grad_template: Pytree, n_workers: int,
                    cfg: StrategyConfig, *, worker_dim: bool = True) -> CommState:
    """Zero-initialized state. ``grad_template`` gives shapes/dtypes of one
    worker's gradient pytree (no worker dim)."""
    sdtype = jnp.bfloat16 if cfg.state_bf16 else jnp.float32

    def zeros_like_s(l):
        shape = (n_workers,) + l.shape if worker_dim else l.shape
        return jnp.zeros(shape, sdtype)

    assert cfg.lazy_rule in LAZY_RULES, cfg.lazy_rule
    assert cfg.compressor in COMPRESSORS, cfg.compressor
    assert cfg.aggregator in AGGREGATORS, cfg.aggregator
    if cfg.compressed or cfg.error_feedback:
        assert cfg.quantized and not cfg.adaptive, (
            "the compressor pipeline / error feedback require a fixed-bit "
            "quantized kind (qgd / laq)")
    if cfg.faults.wire_faulty:
        assert cfg.quantized and not cfg.adaptive and not cfg.compressed, (
            "wire-code bit-flips model the packed fixed-bit payload: they "
            "need a fixed-bit quantized kind (qgd / laq) without the sparse "
            "compressor pipeline")
    wshape = (n_workers,) if worker_dim else ()
    # clocks start at t_bar when first_round_upload: criterion (7b) then
    # forces a dense first round, bootstrapping qhat / the server aggregate.
    clock0 = cfg.criterion.t_bar if (cfg.lazy and cfg.first_round_upload) else 0
    lazy_rule = cfg.lazy_rule if cfg.lazy else "laq7a"
    return CommState(
        qhat=jax.tree.map(zeros_like_s, grad_template),
        server_agg=jax.tree.map(lambda l: jnp.zeros(l.shape, sdtype), grad_template),
        eps_hat_sq=jnp.zeros(wshape, jnp.float32),
        clocks=jnp.full(wshape, clock0, jnp.int32),
        bits_spent=jnp.zeros(wshape, jnp.float32),
        theta_hist=jnp.zeros((cfg.criterion.D,), jnp.float32),
        total_bits=jnp.zeros((), jnp.float32),
        total_uploads=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        lazy=init_lazy_state(lazy_rule, grad_template, n_workers,
                             worker_dim=worker_dim),
        R_anchor=jnp.zeros(wshape, jnp.float32),
        svrg=init_svrg_state(cfg.grad_mode, grad_template, n_workers,
                             worker_dim=worker_dim),
        error=init_error_state(cfg.error_feedback, grad_template, n_workers,
                               worker_dim=worker_dim),
        defense=init_defense_state(cfg.defense, n_workers,
                                   worker_dim=worker_dim),
    )


# ---------------------------------------------------------------------------
# Per-worker update: the heart of LAQ.  Pure; no collectives.
# ---------------------------------------------------------------------------

class WorkerOut(NamedTuple):
    """Result of :func:`worker_update`.

    The leading eight fields keep the historical positional order, so
    *indexed* access (``out[0]``..``out[7]``) and ``zip``-style iteration
    over a prefix stay valid — but the arity grew from 8, so fixed-arity
    tuple unpacking of the old return must move to the named fields.
    """
    delta_masked: Pytree    # masked contribution to the server refinement
    qhat_new: Pytree
    eps_hat_sq_new: jax.Array
    clock_new: jax.Array
    uploaded: jax.Array     # transmission bit: the worker SENT a payload
                            # (drives bits_m / total_uploads even when the
                            # server rejects it)
    bits_m: jax.Array
    R: jax.Array
    width_m: jax.Array      # selected width b_m^k (static width on the
                            # fixed path, 32 for dense uploads)
    lazy_new: LazyState     # updated LASG estimator state
    R_anchor_new: jax.Array  # updated scale-free threshold anchor
    error_new: ErrorState   # updated EF residual (None-gated pass-through
                            # when error_feedback is off)
    committed: jax.Array = True  # commit bit: the server APPLIED the payload
                            # (== uploaded unless defense validation rejected
                            # it; drives qhat/eps/clock/estimator commits)
    defense_new: DefenseState = DefenseState(None, None, None)  # updated
                            # validation state (None-gated pass-through)


def worker_update(grad_m: Pytree, qhat_m: Pytree, eps_hat_sq_m, clock_m,
                  bits_spent_m, theta_hist, alpha, n_workers: int,
                  cfg: StrategyConfig, step=None, lazy_m=None,
                  R_anchor_m=None, params=None, grad_stale_m=None,
                  avail_m=None, error_m=None, ckey_m=None, defense_m=None,
                  flip_m=None, fkey_m=None):
    """One worker's bit-width selection + quantize + skip decision.

    ``lazy_m`` is this worker's :class:`~repro.core.lazy_rules.LazyState`
    slice and ``R_anchor_m`` its scale-free threshold anchor (both optional
    for ``lazy_rule="laq7a"`` with absolute thresholds); ``params`` is the
    current (replicated) iterate, required by the ``lasg_wk2`` / ``lasg_ps``
    rules; ``grad_stale_m`` is the WK2 same-sample second backprop (the
    current minibatch at the worker's stale iterate), required by that rule
    only.  ``avail_m`` is this worker's participation bit (core/engine.py).
    ``error_m`` is this worker's :class:`~repro.core.compressors.ErrorState`
    slice (EF-LAQ: its residual is added back before compressing and
    re-committed on upload) and ``ckey_m`` its rand-k support key
    (``compressor_keys``; ignored by topk).  ``defense_m`` is this worker's
    :class:`~repro.core.defense.DefenseState` slice (required when
    ``cfg.defense.active``); ``flip_m`` / ``fkey_m`` are the wire-fault
    mask bit and flip-position key (``core/faults.py``, bitflip kind only).

    Masking discipline — ONE code path for every way a payload fails to
    commit.  Two bits gate the state commits:

    * ``uploaded`` — the worker transmitted: the (honest, pre-fault) skip
      rule said upload AND the worker was reachable (``avail_m``).  Drives
      the bits/uploads accounting: a transmission costs wire bits whether
      or not the server accepts it.
    * ``committed`` — the server applied the payload: ``uploaded`` AND the
      defense validation accepted it.  Drives every state commit —
      ``delta_masked``, ``qhat``, ``eps_hat_sq``, clock reset, estimator
      snapshots, the EF residual.  Without an active defense ``committed``
      IS ``uploaded`` (no extra ops), so a lazy skip, an unreachable worker
      and a rejected upload all flow through the same masked-commit block:
      no ``qhat`` commit, clock grows, state frozen.  The only asymmetry is
      honest accounting: rejects pay bits, skips/absences do not.

    Returns a :class:`WorkerOut`; ``delta_masked`` is zero unless
    committed.
    """
    p = tree_size(grad_m)
    if lazy_m is None:
        lazy_m = empty_lazy_state()
    if R_anchor_m is None:
        R_anchor_m = jnp.zeros((), jnp.float32)
    if error_m is None:
        error_m = empty_error_state()
    if cfg.compressed or cfg.error_feedback:
        assert cfg.quantized and not cfg.adaptive, (
            "the compressor pipeline / error feedback require a fixed-bit "
            "quantized kind (qgd / laq)")
    if cfg.faults.wire_faulty:
        assert cfg.quantized and not cfg.adaptive and not cfg.compressed, (
            "wire-code bit-flips need the plain fixed-bit quantized path")
    if cfg.error_feedback:
        # EF: compress the residual-corrected gradient g_eff = g + eta e.
        # eta (cfg.ef_damping) tempers the loop gain — the innovation
        # reference already re-injects untransmitted mass implicitly, so
        # undamped EF counts it twice (see docs/compressors.md)
        assert error_m.residual is not None, \
            "error_feedback needs CommState.error (init_comm_state gates it)"
        g_eff = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + cfg.ef_damping * e,
            grad_m, error_m.residual)
    else:
        g_eff = grad_m
    # sidecar count is wire-backend-INDEPENDENT by construction: both
    # backends exchange one f32 radius per leaf (per-leaf mode) or one
    # global radius, so bits_m accounting is identical across backends
    # (asserted in tests/test_wire_backend.py).
    n_sidecars = (len(jax.tree_util.tree_leaves(grad_m))
                  if cfg.per_leaf_radius else 1)
    backend = get_backend(cfg.wire_backend)
    if cfg.adaptive:
        sched = cfg.bit_schedule
        step_ = jnp.zeros((), jnp.int32) if step is None else step
        # pass 1 of the wire pipeline: the backend's radius reduction (the
        # fused backend computes R without materializing the diff tensor)
        diff, R_tree, R = backend.innovation(grad_m, qhat_m,
                                             cfg.per_leaf_radius)
        width_m, onehot, R_anchor_new = select_bits(
            sched, R, bits_spent_m, step_, p, n_radii=n_sidecars,
            R_anchor=R_anchor_m)
        # pass 2 through the backend: the reference backend runs the staged
        # quantize_dynamic/dequantize_dynamic pipeline (moved verbatim into
        # WireBackend.adaptive_roundtrip — bitwise anchor), the fused
        # backend the width-grid-unrolled one-sweep kernel
        q_new, delta, err_sq, innovation_sq = backend.adaptive_roundtrip(
            grad_m, qhat_m, diff, R_tree, sched.grid, onehot)
        bits_if_upload = upload_bits(p, width_m, n_radii=n_sidecars,
                                     bit_sidecar=True)
    elif cfg.compressed:
        # sparsify -> quantize -> pack on the (EF-corrected) innovation:
        # core/wire.py sparse_roundtrip, stages from core/compressors.py
        srt = sparse_roundtrip(backend, g_eff, qhat_m, cfg.effective_bits,
                               static_k(cfg.compressor_k, p), cfg.compressor,
                               key=ckey_m)
        q_new, delta, R = srt.q_new, srt.delta, srt.R
        err_sq, innovation_sq = srt.err_sq, srt.innovation_sq
        bits_if_upload = float(sparse_upload_bits(
            p, static_k(cfg.compressor_k, p), cfg.effective_bits,
            n_radii=2))     # two f32 sidecars: the (lo, hi) grid endpoints
        width_m = jnp.full((), float(cfg.effective_bits), jnp.float32)
    elif cfg.quantized:
        rt = backend.roundtrip(g_eff, qhat_m, cfg.effective_bits,
                               cfg.per_leaf_radius)
        q_new, delta, R = rt.q_new, rt.delta, rt.R_max
        R_tree = rt.R_tree      # the wire-fault layer flips codes per leaf
        # the fused backend emits both criterion moments as in-pass partial
        # sums; the reference backend spends two extra sweeps on them
        err_sq, innovation_sq = rt.err_sq, rt.innovation_sq
        bits_if_upload = float(upload_bits(p, cfg.effective_bits,
                                           n_radii=n_sidecars))
        width_m = jnp.full((), float(cfg.effective_bits), jnp.float32)
    else:
        q_new = jax.tree.map(lambda g: g.astype(jnp.float32), grad_m)
        delta = jax.tree.map(lambda g, q: g - q, q_new, qhat_m)
        R = jnp.zeros((), jnp.float32)
        err_sq = jnp.zeros((), jnp.float32)
        innovation_sq = tree_sq_norm(delta)
        bits_if_upload = float(dense_bits(p))
        width_m = jnp.full((), 32.0, jnp.float32)

    if not cfg.adaptive:
        R_anchor_new = R_anchor_m

    lazy_pre, stats = lazy_m, None
    if cfg.lazy:
        if cfg.lazy_rule == "laq7a":
            skip = should_skip(innovation_sq, theta_hist, alpha, n_workers,
                               err_sq, eps_hat_sq_m, clock_m, cfg.criterion)
        else:
            skip, lazy_pre, stats = lazy_rule_step(
                cfg.lazy_rule, cfg.lasg, cfg.criterion, grad_m=grad_m,
                params=params, lazy_m=lazy_m, innovation_sq=innovation_sq,
                err_sq=err_sq, eps_hat_sq_m=eps_hat_sq_m, clock_m=clock_m,
                theta_hist=theta_hist, alpha=alpha, n_workers=n_workers,
                grad_stale_m=grad_stale_m)
    else:
        skip = jnp.zeros((), bool)
    uploaded = jnp.logical_not(skip)
    if avail_m is not None:
        # participation mask BEFORE the state commits: an unreachable
        # worker must not upload even when the rule (or the 7b staleness
        # bound) demands it — its clock keeps growing and the overdue
        # upload happens at its next available round
        uploaded = jnp.logical_and(uploaded, avail_m)

    if cfg.faults.wire_faulty and flip_m is not None:
        # wire-level fault: MSB flips on this worker's packed codes, AFTER
        # the (honest) skip decision — corruption happens in encode/
        # transit, not in the rule.  The corrupted payload is what both
        # the server aggregate and the worker's own qhat mirror would
        # commit, so the decoded moments are recomputed from it: the
        # defense gate sees what the server sees.
        delta_f = flip_wire_codes(delta, R_tree, cfg.effective_bits, fkey_m,
                                  cfg.faults.bitflip_frac)
        delta = jax.tree.map(lambda a, b: jnp.where(flip_m, b, a),
                             delta, delta_f)
        q_new = jax.tree.map(lambda qh, d: qh.astype(jnp.float32) + d,
                             qhat_m, delta)
        err_sq = tree_sq_norm(jax.tree.map(
            lambda g, qn: g.astype(jnp.float32) - qn, g_eff, q_new))
        innovation_sq = tree_sq_norm(delta)

    if cfg.defense.active:
        # server-side upload validation + norm-clipping on the decoded
        # payload (core/defense.py).  Per-worker-local by construction, so
        # the same code runs per shard in launch/train.py.
        assert defense_m is not None and defense_m.norm_ema is not None, \
            "cfg.defense.active needs CommState.defense (init_comm_state)"
        accept, clip_scale, defense_new = defense_step(
            cfg.defense, defense_m, innovation_sq, err_sq, uploaded)
        committed = jnp.logical_and(uploaded, accept)
        if cfg.defense.clip_mult > 0.0:
            # the SAME scaled delta updates server_agg and the qhat
            # mirror, preserving server_agg == sum_m qhat_m exactly
            delta = jax.tree.map(lambda d: d * clip_scale, delta)
            q_new = jax.tree.map(lambda qh, d: qh.astype(jnp.float32) + d,
                                 qhat_m, delta)
            innovation_sq = innovation_sq * clip_scale * clip_scale
            if cfg.compressed:
                # the sparse path's err_sq is support-restricted; scaling
                # the dequant values rescales it only approximately —
                # exact at scale 1 (the no-clip case), conservative
                # otherwise (documented in docs/robustness.md)
                err_sq = err_sq * clip_scale * clip_scale
            else:
                err_sq = tree_sq_norm(jax.tree.map(
                    lambda g, qn: g.astype(jnp.float32) - qn, g_eff, q_new))
    else:
        committed = uploaded
        defense_new = defense_m if defense_m is not None \
            else empty_defense_state()

    if stats is not None:
        lazy_new = commit_upload(cfg.lazy_rule, cfg.lasg, lazy_pre, committed,
                                 stats, params=params,
                                 innovation_sq=innovation_sq)
    else:
        lazy_new = lazy_pre
    if avail_m is not None:
        # an unreachable worker ran no local computation this round: hold
        # its estimator state (variance/smoothness EMAs, snapshots) and its
        # adaptive threshold anchor as well
        lazy_new = jax.tree.map(lambda n, o: jnp.where(avail_m, n, o),
                                lazy_new, lazy_m)
        R_anchor_new = jnp.where(avail_m, R_anchor_new, R_anchor_m)

    # the single masked-commit block: `committed` (== `uploaded` without an
    # active defense) gates every state commit; `uploaded` alone pays bits.
    # Select, don't multiply: a rejected Inf payload would turn 0 * inf
    # into NaN and poison the server sum through the mask.
    delta_masked = jax.tree.map(
        lambda d: jnp.where(committed, d, jnp.zeros_like(d)), delta)
    qhat_new = jax.tree.map(lambda qn, qh: jnp.where(committed, qn.astype(qh.dtype), qh),
                            q_new, qhat_m)
    eps_hat_sq_new = jnp.where(committed, err_sq, eps_hat_sq_m)
    clock_new = jnp.where(committed, 0, clock_m + 1).astype(jnp.int32)
    bits_m = uploaded.astype(jnp.float32) * bits_if_upload
    if cfg.error_feedback:
        # the residual commits only on a committed upload (a skipped or
        # rejected round changes nothing server-side, so its compression
        # error never happened): e_new = g_eff - q_new — the mass this
        # round's compress dropped
        error_new = ErrorState(residual=jax.tree.map(
            lambda g, qn, e: jnp.where(committed,
                                       g.astype(jnp.float32) - qn, e),
            g_eff, q_new, error_m.residual))
    else:
        error_new = error_m
    return WorkerOut(delta_masked, qhat_new, eps_hat_sq_new, clock_new,
                     uploaded, bits_m, R, width_m, lazy_new, R_anchor_new,
                     error_new, committed, defense_new)


# ---------------------------------------------------------------------------
# Simulated cluster mode (vmap over a leading worker axis).
# ---------------------------------------------------------------------------

def aggregate(state: CommState, grads: Pytree, alpha, cfg: StrategyConfig,
              params: Pytree = None, grads_stale: Pytree = None,
              avail: jax.Array = None, fault_flip: jax.Array = None,
              fault_keys: jax.Array = None):
    """Aggregate per-worker gradients (leading dim W) into the LAQ gradient.

    ``params`` is the current (replicated) iterate — required by the
    ``lasg_wk2`` / ``lasg_ps`` lazy rules, ignored otherwise;
    ``grads_stale`` (leading dim W, same structure as ``grads``) is the WK2
    same-sample second backprop; ``avail`` ([W] bool) is the round's
    participation mask (core/engine.py) — unreachable workers are masked
    exactly like lazy skips; ``fault_flip`` / ``fault_keys`` ([W] bool /
    [W] keys) drive the wire-code bit-flip fault (core/faults.py, engine-
    supplied).  Returns ``(agg_grad, new_state, metrics)``.  The caller
    applies ``theta <- theta - alpha * agg_grad`` (or feeds agg_grad to an
    optimizer) and then calls :func:`finalize_step` with the realized
    parameter change.
    """
    n_workers = state.clocks.shape[0]
    have_stale, have_avail = grads_stale is not None, avail is not None
    have_flip = fault_flip is not None
    have_ckey = cfg.compressor == "randk"
    ckeys = (compressor_keys(cfg.compressor_seed, state.step, n_workers)
             if have_ckey else None)

    def upd(*args):
        # theta_hist / params are replicated across workers: closed over,
        # not vmapped
        (grad_m, qhat_m, eps_m, clock_m, spent_m, lazy_m, anchor_m,
         err_m, defense_m) = args[:9]
        rest = list(args[9:])
        ckey_m = rest.pop(0) if have_ckey else None
        grad_stale_m = rest.pop(0) if have_stale else None
        avail_m = rest.pop(0) if have_avail else None
        flip_m = rest.pop(0) if have_flip else None
        fkey_m = rest.pop(0) if have_flip else None
        return worker_update(grad_m, qhat_m, eps_m, clock_m, spent_m,
                             state.theta_hist, alpha, n_workers, cfg,
                             step=state.step, lazy_m=lazy_m,
                             R_anchor_m=anchor_m, params=params,
                             grad_stale_m=grad_stale_m, avail_m=avail_m,
                             error_m=err_m, ckey_m=ckey_m,
                             defense_m=defense_m, flip_m=flip_m,
                             fkey_m=fkey_m)

    wargs = (grads, state.qhat, state.eps_hat_sq, state.clocks,
             state.bits_spent, state.lazy, state.R_anchor, state.error,
             state.defense)
    if have_ckey:
        wargs = wargs + (ckeys,)
    if have_stale:
        wargs = wargs + (grads_stale,)   # vmap cannot map a None arg
    if have_avail:
        wargs = wargs + (avail,)
    if have_flip:
        wargs = wargs + (fault_flip, fault_keys)
    wu = jax.vmap(upd)(*wargs)

    # Server recursion: agg^k = agg^{k-1} + sum_m deltaQ_m ("sum"), or the
    # robust combination of the committed deltas (core/defense.py) — same
    # scale, bounded drift from the per-worker qhat mirrors (documented in
    # docs/robustness.md).
    if cfg.aggregator == "sum":
        agg = jax.tree.map(lambda a, d: a + jnp.sum(d, axis=0),
                           state.server_agg, wu.delta_masked)
    else:
        robust = robust_aggregate(cfg.aggregator, wu.delta_masked,
                                  wu.committed, cfg.trim_frac)
        agg = jax.tree.map(lambda a, r: a + r, state.server_agg, robust)

    uploaded, bits_m = wu.uploaded, wu.bits_m
    uploads = jnp.sum(uploaded.astype(jnp.int32))
    rejections = jnp.sum(jnp.logical_and(
        uploaded, jnp.logical_not(wu.committed)).astype(jnp.int32))
    bits = jnp.sum(bits_m)
    fup = uploaded.astype(jnp.float32)
    metrics = RoundMetrics(uploads=uploads, bits=bits,
                           mean_skip=1.0 - uploads / n_workers,
                           radius_max=jnp.max(wu.R),
                           mean_bits=jnp.sum(wu.width_m * fup)
                           / jnp.maximum(jnp.sum(fup), 1.0),
                           rejections=rejections)
    new_state = state._replace(
        qhat=wu.qhat_new, server_agg=agg, eps_hat_sq=wu.eps_hat_sq_new,
        clocks=wu.clock_new,
        bits_spent=state.bits_spent + bits_m,
        total_bits=state.total_bits + bits,
        total_uploads=state.total_uploads + uploads,
        step=state.step + 1,
        lazy=wu.lazy_new, R_anchor=wu.R_anchor_new, error=wu.error_new,
        defense=wu.defense_new,
    )
    return agg, new_state, metrics


def finalize_step(state: CommState, theta_diff_sq) -> CommState:
    """Push ||theta^{k+1}-theta^k||^2 into the criterion's history ring."""
    return state._replace(theta_hist=push_history(state.theta_hist, theta_diff_sq))
