"""Variance-aware lazy-aggregation skip rules (LASG; Chen et al., 2020).

The paper's criterion (7a) measures the *stale-gradient difference*
``||Q_m(theta^k) - Q_m(theta_hat_m)||^2`` and skips when it is dominated by
the recent parameter motion.  With full gradients that difference vanishes as
training converges, so skipping is driven by real innovation.  With
*minibatch* gradients it does not vanish: the two gradients are evaluated on
independent samples, so

    E ||g_m^k - g_hat_m||^2  ~=  ||true drift||^2 + sigma_m^2 + sigma_hat_m^2

carries a variance floor, and — because the quantization radius ``R`` (hence
the eq.-7a slack ``3(eps^2 + eps_hat^2)``) inherits the same floor — the
eq.-7a decision degenerates into a noise coin-flip: workers skip (and upload)
on noise, not on innovation.  LASG's fix is to make the variance an explicit
term of the rule.  This module implements both LASG-style rule families on
top of the shared eq.-7 threshold machinery in :mod:`repro.core.criterion`:

``lasg_wk`` — worker-side, variance-corrected stale-gradient difference
    (LASG-WK1 style).  Each worker maintains an EMA estimate of its own
    minibatch-gradient variance (second moments around an EMA first moment,
    both debiased) and skips iff

        ||deltaQ_m^k||^2 + c_var (sigma_m^2 + sigma_hat_m^2)
            <= hist_term + quant_slack,                 and  t_m < t_bar

    i.e. the *expected* error of reusing the stale gradient — true drift
    plus the noise energy baked into both gradients — must be covered by the
    skip dividend.  ``sigma_hat_m^2`` is the variance estimate frozen at the
    worker's last upload (the noise carried by ``qhat``).  Relative to 7a the
    rule only shrinks the skip region (by exactly the variance correction),
    so at high minibatch variance SLAQ-WK uploads strictly more often than
    7a-on-noise — and converges in fewer rounds *to a target loss*, because
    uploaded noise averages out across rounds while noise frozen into a
    skipped worker's stale gradient is re-sent as bias every round
    (benchmarks/lasg_frontier.py measures both effects).

``lasg_wk2`` — worker-side, *same-sample* stale-gradient difference
    (LASG-WK2 style).  Instead of correcting for noise, the worker removes
    it: it re-evaluates the **current** minibatch at the iterate of its last
    upload ``theta_hat_m = theta^{t - tau_m}`` (a second backprop) and skips
    iff

        c_wk2 ||g(theta^k; xi^k) - g(theta_hat_m; xi^k)||^2
            <= hist_term + quant_slack,              and  t_m < t_bar

    Both gradients see the *same* sample ``xi^k``, so the minibatch noise
    cancels in the difference and — by smoothness — what remains is bounded
    by ``L^2 ||theta^k - theta_hat_m||^2``: a noise-free innovation proxy,
    exactly the deterministic rule's behaviour recovered at the price of 2x
    worker compute.  No variance estimator, no EMA: the only state is the
    stale-iterate snapshot ``theta_last`` (shared with ``lasg_ps``).  The
    second backprop ``g(theta_hat_m; xi^k)`` cannot be computed here (it
    needs the loss closure and the live minibatch), so the runner threads it
    in as ``grad_stale_m`` (``run_stochastic`` / the sharded step both do).
    Relative to ``lasg_wk`` the criterion is *sharper*: the WK correction
    over-estimates the reuse error by the (conservative, EMA-lagged)
    variance term, so at matched thresholds WK2 skips at least as often —
    property- and contract-tested in tests/test_convergence_contracts.py.

``lasg_ps`` — server-side, parameter-difference trigger (LASG-PS style).
    The server knows ``theta^k`` and each worker's iterate at its last upload
    ``theta_hat_m`` without any worker computation, and by smoothness
    ``||grad f_m(theta^k) - grad f_m(theta_hat_m)||^2 <= L_m^2 ||theta^k -
    theta_hat_m||^2``, so parameter drift is a noise-FREE proxy for gradient
    innovation.  Skip iff

        c_ps * Lhat_m^2 * ||theta^k - theta_hat_m||^2
            <= hist_term + quant_slack,                 and  t_m < t_bar

    The smoothness constant the LAG/LASG analyses carry as ``L_m`` is not a
    tunable here: ``Lhat_m^2`` is estimated online as a debiased EMA of the
    realized ratios ``||deltaQ_m||^2 / ||theta^k - theta_hat_m||^2`` observed
    at upload rounds, so the rule is scale-free — no per-workload constant
    (the same anchoring idea as the relative bit-width thresholds in
    :mod:`repro.core.adaptive`).  Until the first ratio is observed the rule
    forces uploads (infinite LHS), which the dense bootstrap round satisfies.

``laq7a`` — the paper's criterion, unchanged (:mod:`repro.core.criterion`);
    the deterministic default and the stochastic strawman.

Selection is via ``StrategyConfig.lazy_rule``; constants live in
:class:`LasgConfig`; per-worker estimator state lives in :class:`LazyState`
(a ``CommState`` field, leading worker axis in simulated mode, one slice per
shard in sharded mode).  Symbol-to-paper mapping: ``docs/paper-map.md``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .criterion import CriterionConfig, rhs_threshold
from .quantize import tree_sq_norm

Pytree = object

LAZY_RULES = ("laq7a", "lasg_wk", "lasg_wk2", "lasg_ps")

# rules whose LazyState carries the stale-iterate snapshot ``theta_last``
_THETA_LAST_RULES = ("lasg_wk2", "lasg_ps")


class LasgConfig(NamedTuple):
    """Constants of the LASG rules (paper-map: docs/paper-map.md).

    ``c_var`` — weight on the WK variance correction ``sigma^2 +
    sigma_hat^2`` (the LASG analysis carries a larger constant; 1.0 applies
    the de-biased noise energy exactly once).  ``c_wk2`` — weight on the
    WK2 same-sample difference (1.0 compares the noise-free reuse error to
    the plain eq.-7 dividend).  ``c_ps`` — safety factor on the PS drift
    trigger (multiplies the online ``Lhat^2``).  ``var_decay`` — EMA decay
    for both the variance estimator (WK) and the smoothness-ratio estimator
    (PS).
    """
    c_var: float = 1.0
    c_wk2: float = 1.0
    c_ps: float = 1.0
    var_decay: float = 0.9


class LazyState(NamedTuple):
    """Per-worker estimator state for the LASG rules.

    Pytree fields are ``None`` for rules that do not need them, so ``laq7a``
    runs carry only three scalars per worker.  Always float32 (never bf16:
    the estimators feed threshold comparisons, not the wire).
    """
    grad_ema: Optional[Pytree]   # WK: EMA first moment of minibatch grads
    stat_ema: jax.Array          # WK: raw EMA of squared deviations (sigma^2)
                                 # PS: raw EMA of innovation/drift ratios (Lhat^2)
    stat_count: jax.Array        # debias counter for stat_ema; WK2: upload
                                 # counter (bootstrap guard: 0 forces upload)
    sigma_hat_sq: jax.Array      # WK: variance estimate frozen at last upload
    theta_last: Optional[Pytree]  # PS/WK2: iterate at the worker's last upload


def empty_lazy_state() -> LazyState:
    """Scalar placeholder for callers that bypass ``init_comm_state``."""
    z = jnp.zeros((), jnp.float32)
    return LazyState(None, z, z, z, None)


def init_lazy_state(rule: str, grad_template: Pytree, n_workers: int,
                    *, worker_dim: bool = True) -> LazyState:
    """Initial estimator state for ``rule``.

    ``grad_template`` gives one worker's gradient (== parameter) pytree;
    pytree fields get a leading worker dim in simulated mode.  Estimator
    EMAs start at zero; ``theta_last`` starts at the template *values* (the
    initial iterate — both runners and the launch path pass the actual
    ``params0`` here), so the bootstrap round sees zero drift and the
    ``Lhat^2`` ratio EMA never observes a ratio against a placeholder
    iterate (with a zero-filled ``theta_last`` and nonzero ``theta_0``,
    the first "drift" would be ``||theta_0||^2`` and poison the estimate).
    """
    assert rule in LAZY_RULES, rule
    wshape = (n_workers,) if worker_dim else ()

    def zeros_like_w(l):
        shape = wshape + l.shape
        return jnp.zeros(shape, jnp.float32)

    def snapshot_w(l):
        return jnp.broadcast_to(jnp.asarray(l, jnp.float32), wshape + l.shape)

    return LazyState(
        grad_ema=(jax.tree.map(zeros_like_w, grad_template)
                  if rule == "lasg_wk" else None),
        stat_ema=jnp.zeros(wshape, jnp.float32),
        stat_count=jnp.zeros(wshape, jnp.float32),
        sigma_hat_sq=jnp.zeros(wshape, jnp.float32),
        theta_last=(jax.tree.map(snapshot_w, grad_template)
                    if rule in _THETA_LAST_RULES else None),
    )


# ---------------------------------------------------------------------------
# WK: per-worker minibatch-gradient variance estimator (EMA second moments).
# ---------------------------------------------------------------------------

def variance_update(lazy_m: LazyState, grad_m: Pytree, cfg: LasgConfig):
    """One EMA step of the worker's variance estimator.

    Tracks the first moment ``m`` (EMA of minibatch gradients) and the raw
    second moment ``v`` (EMA of ``||g - m_debiased||^2``); returns the
    debiased variance estimate ``sigma_sq`` and the updated ``(grad_ema,
    stat_ema, stat_count)``.  With zero history the deviation is ``||g||^2``
    — a deliberate overestimate that keeps the WK rule conservative until
    the estimator warms up (round 1 is dense anyway); during optimization
    the mean lags the drift, which again only overestimates sigma^2.
    """
    d = cfg.var_decay
    count = lazy_m.stat_count
    # debiased previous mean (zeros/1 at count == 0 -> deviation = ||g||^2)
    denom = jnp.where(count > 0, 1.0 - d ** count, 1.0)
    dev_sq = tree_sq_norm(jax.tree.map(
        lambda g, m: g.astype(jnp.float32) - m / denom,
        grad_m, lazy_m.grad_ema))
    stat_new = d * lazy_m.stat_ema + (1.0 - d) * dev_sq
    count_new = count + 1.0
    sigma_sq = stat_new / (1.0 - d ** count_new)
    ema_new = jax.tree.map(lambda m, g: d * m + (1.0 - d) * g.astype(jnp.float32),
                           lazy_m.grad_ema, grad_m)
    return sigma_sq, lazy_m._replace(grad_ema=ema_new, stat_ema=stat_new,
                                     stat_count=count_new)


def smoothness_sq(lazy_m: LazyState, cfg: LasgConfig):
    """PS: debiased ``Lhat_m^2`` from the ratio EMA; +inf before the first
    observed (innovation, drift) pair, which forces an upload."""
    d = cfg.var_decay
    est = lazy_m.stat_ema / jnp.maximum(1.0 - d ** lazy_m.stat_count, 1e-12)
    return jnp.where(lazy_m.stat_count > 0, est, jnp.inf)


# ---------------------------------------------------------------------------
# The rules.  All share criterion.rhs_threshold (hist term + quant slack)
# and the (7b) staleness bound; they differ only in the LHS.
# ---------------------------------------------------------------------------

def rule_lhs(rule: str, lasg: LasgConfig, *, innovation_sq=None,
             sigma_sq=None, sigma_hat_sq=None, drift_sq=None, L_sq=None,
             same_diff_sq=None):
    """Left-hand side of the skip comparison for ``rule`` (see module
    docstring for the formulas)."""
    if rule == "laq7a":
        return innovation_sq
    if rule == "lasg_wk":
        return innovation_sq + lasg.c_var * (sigma_sq + sigma_hat_sq)
    if rule == "lasg_wk2":
        return lasg.c_wk2 * same_diff_sq
    if rule == "lasg_ps":
        # explicit guard: before the first ratio observation L_sq is +inf
        # and drift may be 0 — force the upload rather than rely on
        # inf * 0 = nan falling out of the <= comparison
        return jnp.where(jnp.isfinite(L_sq), lasg.c_ps * L_sq * drift_sq,
                         jnp.inf)
    raise ValueError(f"unknown lazy rule {rule!r}; have {LAZY_RULES}")


def should_skip_rule(rule: str, lasg: LasgConfig, crit: CriterionConfig, *,
                     theta_hist, alpha, M: int, eps_sq, eps_hat_sq, clock,
                     innovation_sq=None, sigma_sq=None, sigma_hat_sq=None,
                     drift_sq=None, L_sq=None, same_diff_sq=None):
    """Boolean skip decision for one worker under any of the four rules
    (vmap over workers upstream, exactly like criterion.should_skip)."""
    lhs = rule_lhs(rule, lasg, innovation_sq=innovation_sq, sigma_sq=sigma_sq,
                   sigma_hat_sq=sigma_hat_sq, drift_sq=drift_sq, L_sq=L_sq,
                   same_diff_sq=same_diff_sq)
    rhs = rhs_threshold(theta_hist, alpha, M, eps_sq, eps_hat_sq, crit)
    return jnp.logical_and(lhs <= rhs, clock < crit.t_bar)


# ---------------------------------------------------------------------------
# Per-worker driver used by strategy.worker_update: evaluate the rule, then
# commit the upload-conditional state once the decision is known.
# ---------------------------------------------------------------------------

def lazy_rule_step(rule: str, lasg: LasgConfig, crit: CriterionConfig, *,
                   grad_m, params, lazy_m: LazyState, innovation_sq, err_sq,
                   eps_hat_sq_m, clock_m, theta_hist, alpha, n_workers: int,
                   grad_stale_m=None):
    """Evaluate ``rule`` for one worker.

    ``grad_stale_m`` is the WK2 second backprop — the *current* minibatch
    re-evaluated at this worker's stale iterate ``theta_last`` (computed by
    the runner, which owns the loss closure and the live batch).

    Returns ``(skip, lazy_pre, stats)`` where ``lazy_pre`` holds the
    estimator fields that update every round regardless of the decision and
    ``stats`` the per-round scalars :func:`commit_upload` needs to refresh
    the upload-frozen fields.
    """
    sigma_sq = jnp.zeros((), jnp.float32)
    drift_sq = jnp.zeros((), jnp.float32)
    same_diff_sq = jnp.zeros((), jnp.float32)
    lazy_pre = lazy_m
    if rule == "lasg_wk":
        if lazy_m.grad_ema is None:
            raise ValueError("lazy_rule='lasg_wk' needs LazyState.grad_ema; "
                             "allocate the state with init_comm_state / "
                             "init_lazy_state for this rule")
        sigma_sq, lazy_pre = variance_update(lazy_m, grad_m, lasg)
    elif rule == "lasg_wk2":
        if params is None:
            raise ValueError("lazy_rule='lasg_wk2' needs the current params "
                             "threaded into worker_update/aggregate (the "
                             "upload commit snapshots theta_last from them)")
        if grad_stale_m is None:
            raise ValueError("lazy_rule='lasg_wk2' needs grad_stale_m — the "
                             "current minibatch's gradient at the stale "
                             "iterate (the runner computes this second "
                             "backprop and threads it through aggregate / "
                             "worker_update as grads_stale)")
        if lazy_m.theta_last is None:
            raise ValueError("lazy_rule='lasg_wk2' needs LazyState.theta_last; "
                             "allocate the state with init_comm_state / "
                             "init_lazy_state for this rule")
        same_diff_sq = tree_sq_norm(jax.tree.map(
            lambda g, gs: g.astype(jnp.float32) - gs.astype(jnp.float32),
            grad_m, grad_stale_m))
        # bootstrap guard (mirrors lasg_ps): until this worker's first
        # upload, theta_last is the init-time snapshot of the CURRENT
        # iterate, so the same-sample difference is identically zero and
        # every worker would skip; with first_round_upload=False that
        # freeze self-sustains (params never move -> the difference stays
        # zero) until (7b) breaks it t_bar rounds later.  Force the upload
        # until the first commit (stat_count doubles as the upload counter
        # for this rule).
        same_diff_sq = jnp.where(lazy_m.stat_count > 0, same_diff_sq,
                                 jnp.inf)
    elif rule == "lasg_ps":
        if params is None:
            raise ValueError("lazy_rule='lasg_ps' needs the current params "
                             "threaded into worker_update/aggregate")
        if lazy_m.theta_last is None:
            raise ValueError("lazy_rule='lasg_ps' needs LazyState.theta_last; "
                             "allocate the state with init_comm_state / "
                             "init_lazy_state for this rule")
        drift_sq = tree_sq_norm(jax.tree.map(
            lambda p, t: p.astype(jnp.float32) - t, params, lazy_m.theta_last))
    skip = should_skip_rule(
        rule, lasg, crit, theta_hist=theta_hist, alpha=alpha, M=n_workers,
        eps_sq=err_sq, eps_hat_sq=eps_hat_sq_m, clock=clock_m,
        innovation_sq=innovation_sq, sigma_sq=sigma_sq,
        sigma_hat_sq=lazy_m.sigma_hat_sq, drift_sq=drift_sq,
        L_sq=smoothness_sq(lazy_m, lasg) if rule == "lasg_ps" else None,
        same_diff_sq=same_diff_sq)
    return skip, lazy_pre, {"sigma_sq": sigma_sq, "drift_sq": drift_sq}


def commit_upload(rule: str, lasg: LasgConfig, lazy_pre: LazyState, uploaded,
                  stats, *, params, innovation_sq) -> LazyState:
    """Refresh the upload-frozen estimator fields.

    WK freezes the current variance estimate into ``sigma_hat_sq`` (the
    noise now baked into ``qhat``).  WK2 snapshots ``theta_last`` — the
    iterate the next rounds' second backprops re-evaluate.  PS snapshots
    ``theta_last`` and feeds the realized ``innovation/drift`` ratio into
    the ``Lhat^2`` EMA — only when drift is nonzero, so the bootstrap round
    (theta unchanged) cannot poison the estimator.
    """
    out = lazy_pre
    if rule == "lasg_wk":
        out = out._replace(sigma_hat_sq=jnp.where(
            uploaded, stats["sigma_sq"], lazy_pre.sigma_hat_sq))
    elif rule == "lasg_wk2":
        fup = uploaded.astype(jnp.float32)
        out = out._replace(
            theta_last=jax.tree.map(
                lambda p, t: fup * p.astype(jnp.float32) + (1.0 - fup) * t,
                params, lazy_pre.theta_last),
            # upload counter: the rule's bootstrap guard forces uploads
            # while this is zero (see lazy_rule_step)
            stat_count=lazy_pre.stat_count + fup)
    elif rule == "lasg_ps":
        drift_sq = stats["drift_sq"]
        observe = jnp.logical_and(uploaded, drift_sq > 1e-20)
        ratio = innovation_sq / jnp.maximum(drift_sq, 1e-20)
        d = lasg.var_decay
        stat_new = jnp.where(observe,
                             d * lazy_pre.stat_ema + (1.0 - d) * ratio,
                             lazy_pre.stat_ema)
        count_new = jnp.where(observe, lazy_pre.stat_count + 1.0,
                              lazy_pre.stat_count)
        fup = uploaded.astype(jnp.float32)
        theta_new = jax.tree.map(
            lambda p, t: fup * p.astype(jnp.float32) + (1.0 - fup) * t,
            params, lazy_pre.theta_last)
        out = out._replace(stat_ema=stat_new, stat_count=count_new,
                           theta_last=theta_new)
    return out
