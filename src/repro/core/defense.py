"""Fault-tolerant aggregation: the defense half of the robustness subsystem
(:mod:`repro.core.faults` is the injection half).

Three independent server-side defenses, all selected via ``StrategyConfig``
and all *per-worker-local* except the aggregator — so validation and
clipping run unchanged inside the sharded step (``launch/train.py``), where
a worker only ever sees its own slice:

* **Upload validation** (:class:`DefenseConfig` ``validate`` /
  ``gate_mult``) — a finite-check and a norm-gate on the decoded payload's
  innovation energy ``||deltaQ_m||^2`` against a per-worker EMA of the
  worker's own *accepted* uploads.  A rejected upload is masked **exactly
  like a lazy skip**: no ``qhat`` commit, no server-aggregate contribution,
  the clock keeps growing (so criterion (7b) forces a retry), and the wire
  bits are still counted — the worker *did* transmit; the server just
  refused to apply the payload.  That accounting invariant (rejected ==
  forced skip, bits honest) is what keeps every bits-to-target claim
  meaningful under faults, and is contract-tested.

* **Norm-clipping** (``clip_mult``) — instead of (or in addition to)
  rejecting, scale an over-norm innovation down to the clip radius before
  committing.  The *same* scaled delta updates ``server_agg`` and the
  worker's ``qhat`` mirror, so the recursion invariant ``server_agg ==
  sum_m qhat_m`` is exactly preserved.  Clipping bounds what a Byzantine
  scaling attack can inject per round to ``O(sqrt(clip_mult * ema))``.

* **Robust aggregation** (``StrategyConfig.aggregator``:
  ``"trimmed_mean"`` / ``"median"``) — replace the sum over committed
  per-worker dequantized deltas with a coordinate-wise trimmed mean or
  median, rescaled by the committed count to stay on the sum's scale.
  This breaks the exact recursion invariant (each worker's ``qhat`` still
  commits its own delta); the drift is bounded by the per-round innovation
  spread and shrinks as innovations decay — documented in
  ``docs/robustness.md``.  Simulated engine only: a coordinate-wise sort
  across workers needs the full worker axis, which the 0.4.x partial-auto
  sharded step cannot regather (``launch/train.py`` asserts).

Plus the **divergence watchdog** (:func:`run_with_watchdog`): a host-side
harness around ``RoundEngine.run_from`` that snapshots ``(params,
CommState, pstate)`` through :mod:`repro.checkpoint` every healthy chunk,
detects loss explosion / non-finite loss, rolls back to the last good
snapshot and resumes — optionally *escalating* the defense config first
(faults replay deterministically from their streams, so a plain resume
would hit the identical fault; escalation changes the outcome, not the
fault).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import load_checkpoint, save_checkpoint

Pytree = object

AGGREGATORS = ("sum", "trimmed_mean", "median")


class DefenseConfig(NamedTuple):
    """Static server-side defense knobs (``StrategyConfig.defense``).

    All-off (the default) compiles the exact undefended round — the
    defended-at-fault-rate-0 bits overhead is exactly zero, and fault-free
    trajectories stay bitwise identical (asserted by the engine parity
    goldens and ``benchmarks/fault_frontier.py``).
    """
    validate: bool = False      # finite-check decoded payloads
    gate_mult: float = 0.0      # > 0: reject uploads whose innovation energy
                                # exceeds gate_mult x the worker's accepted-
                                # upload EMA (warm-up: first accepted upload
                                # is finite-checked only)
    gate_decay: float = 0.9     # EMA decay of the per-worker norm estimate
    clip_mult: float = 0.0      # > 0: scale over-norm innovations down to
                                # sqrt(clip_mult x ema) before committing
    reconcile_crashes: bool = True  # subtract a crashed worker's stale qhat
                                # from server_agg (keeps the recursion
                                # invariant; False = the undefended server)

    @property
    def active(self) -> bool:
        """True iff any per-upload defense state/logic is needed."""
        return self.validate or self.gate_mult > 0.0 or self.clip_mult > 0.0


class DefenseState(NamedTuple):
    """Per-worker server-side validation state (a ``CommState`` field).

    ``None``-gated exactly like ``LazyState`` / ``SvrgState`` /
    ``ErrorState``: with ``DefenseConfig.active`` False the fields vanish
    from the flattened state, so undefended runs carry zero extra leaves.
    Leading worker dim in simulated mode, per-shard slice in sharded mode.
    """
    norm_ema: Optional[jax.Array]    # raw EMA of accepted ||deltaQ_m||^2
    norm_count: Optional[jax.Array]  # debias counter (0 = warm-up)
    rejects: Optional[jax.Array]     # cumulative rejected uploads (int32) —
                                     # the accounting ledger: a rejected
                                     # upload pays bits but commits nothing


def empty_defense_state() -> DefenseState:
    return DefenseState(None, None, None)


def init_defense_state(dc: DefenseConfig, n_workers: int,
                       *, worker_dim: bool = True) -> DefenseState:
    if not dc.active:
        return empty_defense_state()
    wshape = (n_workers,) if worker_dim else ()
    return DefenseState(norm_ema=jnp.zeros(wshape, jnp.float32),
                        norm_count=jnp.zeros(wshape, jnp.float32),
                        rejects=jnp.zeros(wshape, jnp.int32))


def defense_step(dc: DefenseConfig, ds_m: DefenseState, innovation_sq,
                 err_sq, uploaded):
    """One worker's upload validation + clip decision (vmapped upstream,
    or per-shard in the sharded step — no cross-worker communication).

    ``innovation_sq`` is the decoded payload's energy ``||deltaQ_m||^2``
    (post wire faults: what the server actually received) and ``err_sq``
    the upload's quantization-error moment — the value that would commit
    into ``eps_hat_sq``.  Both are finite-checked under ``validate``: a
    NaN *gradient* quantizes to a zero delta (the R > 0 guard), so its
    innovation is a perfectly finite 0 — the poison rides in the eps-hat
    moment, which would turn the worker's criterion RHS NaN and destroy
    its skip economics forever.  ``uploaded`` is the transmission bit (the
    worker sent a payload this round).

    Returns ``(accept, scale, ds_new)``: the acceptance bit, the clip
    factor in ``(0, 1]`` to apply to the committed delta, and the updated
    per-worker state.  The norm EMA advances only on *accepted* commits
    (with the post-clip energy — the mass that actually entered the
    aggregate); the reject counter only on rejected transmissions.
    """
    assert dc.active and ds_m.norm_ema is not None, \
        "defense_step needs an allocated DefenseState (init_defense_state)"
    d = dc.gate_decay
    count = ds_m.norm_count
    warm = count > 0
    ema = ds_m.norm_ema / jnp.where(warm, 1.0 - d ** count, 1.0)

    accept = jnp.ones((), bool)
    if dc.validate:
        accept = jnp.logical_and(accept, jnp.logical_and(
            jnp.isfinite(innovation_sq), jnp.isfinite(err_sq)))
    if dc.gate_mult > 0.0:
        # warm-up accepts anything finite (there is no estimate to gate
        # against); a NaN/Inf energy fails the <= and is rejected even
        # without the explicit finite-check
        gate_ok = jnp.where(warm, innovation_sq <= dc.gate_mult * ema,
                            jnp.isfinite(innovation_sq))
        accept = jnp.logical_and(accept, gate_ok)
    if dc.clip_mult > 0.0:
        over = jnp.logical_and(warm, innovation_sq > dc.clip_mult * ema)
        scale = jnp.where(
            over,
            jnp.sqrt(dc.clip_mult * ema
                     / jnp.maximum(innovation_sq, 1e-30)),
            jnp.ones((), jnp.float32))
    else:
        scale = jnp.ones((), jnp.float32)

    committed = jnp.logical_and(uploaded, accept)
    rejected = jnp.logical_and(uploaded, jnp.logical_not(accept))
    inn_committed = innovation_sq * scale * scale
    ds_new = DefenseState(
        norm_ema=jnp.where(committed,
                           d * ds_m.norm_ema + (1.0 - d) * inn_committed,
                           ds_m.norm_ema),
        norm_count=jnp.where(committed, count + 1.0, count),
        rejects=ds_m.rejects + rejected.astype(jnp.int32))
    return accept, scale, ds_new


# ---------------------------------------------------------------------------
# Robust aggregation over the per-worker dequantized deltas.
# ---------------------------------------------------------------------------

def robust_aggregate(aggregator: str, delta_masked: Pytree,
                     committed: jax.Array, trim_frac: float) -> Pytree:
    """Coordinate-wise robust combination of the committed deltas.

    ``delta_masked`` carries a leading worker axis W (non-committed lanes
    already zeroed); ``committed`` is the [W] commit mask.  Non-committed
    lanes are pushed to +BIG before a per-coordinate sort, so exactly the
    ``n`` committed values occupy the sorted prefix (NaNs among them sort
    last and are trimmed as the largest).  The result is rescaled by ``n``
    to stay on the plain sum's scale, so the server recursion and the
    ``-alpha * agg`` update are unchanged downstream.

    ``trimmed_mean`` drops the ``t = max(1, floor(trim_frac * W))``
    smallest and largest committed coordinates; when ``n <= 2t`` committed
    workers remain it degrades to the plain masked sum (nothing left to
    average).  ``median`` takes the coordinate-wise median of the
    committed values.
    """
    assert aggregator in ("trimmed_mean", "median"), aggregator
    W = committed.shape[0]
    n = jnp.sum(committed.astype(jnp.int32))
    nf = n.astype(jnp.float32)
    BIG = jnp.float32(3.0e38)
    t = max(1, int(np.floor(trim_frac * W)))

    def leaf(d):
        mb = committed.reshape((-1,) + (1,) * (d.ndim - 1))
        plain = jnp.sum(jnp.where(mb, d, 0.0), axis=0)
        xs = jnp.sort(jnp.where(mb, d, BIG), axis=0)
        if aggregator == "median":
            med = 0.5 * (xs[jnp.maximum((n - 1) // 2, 0)]
                         + xs[jnp.maximum(n // 2, 0)])
            return jnp.where(n > 0, med * nf, jnp.zeros_like(plain))
        idx = jnp.arange(W).reshape((-1,) + (1,) * (d.ndim - 1))
        keep = jnp.logical_and(idx >= t, idx < n - t)
        cnt = (n - 2 * t).astype(jnp.float32)
        mean = (jnp.sum(jnp.where(keep, xs, 0.0), axis=0)
                / jnp.maximum(cnt, 1.0))
        return jnp.where(cnt > 0, mean * nf, plain)

    return jax.tree.map(leaf, delta_masked)


# ---------------------------------------------------------------------------
# Divergence watchdog: snapshot / detect / rollback / escalate.
# ---------------------------------------------------------------------------

class WatchdogConfig(NamedTuple):
    chunk: int = 25             # rounds per segment between health checks
    explode_mult: float = 25.0  # loss > mult x best healthy loss => explosion
    max_rollbacks: int = 8      # give up (flagged in the log) after this many


def migrate_carry(old_carry, fresh_carry):
    """Graft a rolled-back carry onto a freshly initialized one.

    Used after a watchdog escalation rebuilt the engine: state fields whose
    pytree structure and shapes survive the config change (params, qhat,
    clocks, estimator state, ...) keep their rolled-back values; fields the
    escalation (re)allocated — e.g. a newly enabled ``DefenseState`` — keep
    their fresh initialization.  Field-by-field over the ``CommState``
    NamedTuple, so the decision is per-subsystem, not all-or-nothing.
    """
    params_old, cst_old, ps_old = old_carry
    _, cst_fresh, ps_fresh = fresh_carry

    def graft(o, f):
        if (jax.tree_util.tree_structure(o)
                != jax.tree_util.tree_structure(f)):
            return f
        lo, lf = jax.tree_util.tree_leaves(o), jax.tree_util.tree_leaves(f)
        if any(a.shape != b.shape for a, b in zip(lo, lf)):
            return f
        return o

    cst = type(cst_fresh)(*(graft(o, f) for o, f in zip(cst_old, cst_fresh)))
    return params_old, cst, graft(ps_old, ps_fresh)


def run_with_watchdog(engine, params0, steps: int, *, ckpt_path: str,
                      wd: WatchdogConfig = WatchdogConfig(), escalate=None):
    """Run ``engine`` for ``steps`` rounds under divergence supervision.

    Scans ``wd.chunk`` rounds at a time via ``engine.run_from``; after each
    chunk the host checks the recorded losses.  A healthy chunk advances
    the run and snapshots the full carry (params + ``CommState`` +
    participation state) to ``ckpt_path`` via :mod:`repro.checkpoint`; an
    unhealthy one (non-finite loss, or loss above ``explode_mult`` x the
    best healthy loss) rolls the carry back to the last snapshot — the
    resumed run continues with its ``CommState`` (clocks, qhat, totals)
    intact.  ``escalate(engine) -> engine`` (optional) is applied on every
    rollback: fault streams are deterministic in the round index, so a
    plain replay hits the identical fault — escalation (e.g. enabling
    validation) changes how the server handles it.  Wasted rounds/bits are
    logged, and totals in the final trajectory count only the surviving
    path (the rollback restored the accounting state too).

    Returns ``(result, log, final_carry)``: the concatenated healthy
    :class:`~repro.core.engine.RunResult`, a dict with ``rollbacks`` /
    ``wasted_rounds`` / ``wasted_bits`` / ``gave_up``, and the final carry
    (its ``CommState`` holds the defense ledgers).
    """
    from .engine import RunResult

    carry = engine.init_carry(params0)
    save_checkpoint(ckpt_path, carry, 0)
    good, best = 0, float("inf")
    chunks = []
    log = {"rollbacks": [], "wasted_rounds": 0, "wasted_bits": 0.0,
           "gave_up": False}
    while good < steps:
        n = min(wd.chunk, steps - good)
        start_bits = float(np.asarray(carry[1].total_bits))
        carry2, rr = engine.run_from(carry, n)
        loss = np.asarray(rr.loss)
        finite = bool(np.all(np.isfinite(loss)))
        exploded = (np.isfinite(best)
                    and float(np.nanmin(loss)) > wd.explode_mult * best)
        if finite and not exploded:
            carry = carry2
            chunks.append(rr)
            good += n
            best = min(best, float(loss.min()))
            save_checkpoint(ckpt_path, carry, good)
            continue
        log["wasted_rounds"] += n
        log["wasted_bits"] += float(np.asarray(carry2[1].total_bits)) \
            - start_bits
        log["rollbacks"].append({
            "round": good,
            "reason": "nonfinite-loss" if not finite else "loss-explosion"})
        if len(log["rollbacks"]) > wd.max_rollbacks:
            log["gave_up"] = True
            break
        carry, _ = load_checkpoint(ckpt_path, carry)
        if escalate is not None:
            engine = escalate(engine)
            carry = migrate_carry(carry, engine.init_carry(carry[0]))
            # re-snapshot so a second rollback restores the POST-escalation
            # state structure
            save_checkpoint(ckpt_path, carry, good)

    def cat(field):
        vals = [getattr(c, field) for c in chunks]
        if not chunks or vals[0] is None:
            return None
        return np.concatenate([np.asarray(v) for v in vals])

    result = RunResult(params=carry[0], loss=cat("loss"),
                       grad_norm_sq=cat("grad_norm_sq"),
                       cum_uploads=cat("cum_uploads"),
                       cum_bits=cat("cum_bits"), quant_err=cat("quant_err"),
                       mean_bits=cat("mean_bits"))
    return result, log, carry
