"""Lazy-replica publishing: the LAQ wire as a model-delta CDN.

The paper's lazy rule (eq. 7a) skips worker *uploads* whose innovation is
below a drift threshold.  This module applies the same change-detection
idea on the **push** side: a trainer continuously publishes *quantized
parameter deltas* to a fleet of inference replicas, and skips the push
entirely while the parameters have not moved enough to matter — LAG's
skip rule as generic change detection (Chen et al., 2018), with DGC-style
delta compression (Lin et al., 2018) making the continuous weight sync
bandwidth-feasible.  Nothing here touches training; the publisher is a
passive observer of the parameter stream.

Protocol (normative spec: ``docs/serving.md``; byte semantics shared with
the upload wire, ``docs/wire-format.md``):

* The publisher tracks ``theta_pub`` — the fleet's dequantize-accumulated
  view of the parameters, maintained with exactly the upload path's
  ``qhat`` recursion: after a quantized push,
  ``theta_pub <- theta_pub + dequant(quant(theta - theta_pub))``, so the
  quantization error does NOT accumulate across pushes (each push
  quantizes the *remaining* difference).
* Push decision — the lazy rule.  The innovation radius
  ``R = max_leaf ||theta - theta_pub||_inf`` is compared against a
  *scale-free relative threshold*: ``push iff R > threshold * A`` where
  ``A`` is the decaying peak envelope ``A^k = max(R^k, anchor_decay *
  A^{k-1})`` — literally the ``BitSchedule`` rel-anchor machinery of
  :mod:`repro.core.adaptive` (``threshold=0`` always pushes;
  ``threshold >= 1`` never pushes lazily, leaving resync-only mode).
* Bounded staleness.  Every skipped round increments ``rounds_behind``;
  when it would exceed ``max_staleness`` the publisher sends a
  **full-precision resync** (raw f32 parameters, ``dense_bits(p)`` on the
  wire) that restores *bitwise* equality between replica and trainer and
  resets the error recursion.  ``max_staleness=0`` degenerates to
  always-push-float32 (the serving baseline).
* Adaptive width.  With ``bit_schedule`` set (a rel-mode
  :class:`~repro.core.adaptive.BitSchedule`), the per-push width is chosen
  by :func:`~repro.core.adaptive.select_bits` from the shared anchor and
  announced in the message (the 8-bit width sidecar of the wire spec).

The wire content of a push is produced by the pluggable
:class:`~repro.core.wire.WireBackend` **one leaf at a time** (the per-leaf
streamed idiom of the sharded ``_packed_aggregate``): per leaf, innovation
-> quantize -> pack before the next leaf is touched, so the transient
footprint is O(max leaf), and the replica decodes with the same per-leaf
streaming.  Per-leaf radii are required (``per_leaf_radius`` semantics):
parameter-delta scales differ by orders of magnitude between embedding /
norm / projection leaves, exactly the bucketing argument of the training
wire.

Bitwise contract (pinned by tests/test_replica.py and the
``serve_frontier`` harness on BOTH wire backends): a replica that applies
every message reproduces ``theta_pub`` bit-for-bit — the decode path
(:func:`repro.core.wire.delta_of_codes` on the unpacked payload) is
expression-identical to the publisher's ``q_new`` accumulation — and a
resync restores bitwise equality with the trainer.  While skipping, the
staleness drift is bounded: ``||theta - replica||_inf = R <= threshold *
A`` on every skipped round (plus ``tau(b) * R_push`` quantization error
after the preceding push, the paper's Fig. 1 guarantee).

Everything here is host-side orchestration over device arrays: the
publisher runs between jitted trainer rounds, not inside them.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adaptive import BitSchedule, select_bits
from .quantize import dense_bits, tree_size, unpack_codes, upload_bits
from .wire import delta_of_codes, get_backend

Pytree = object


class PublishConfig(NamedTuple):
    """Publisher-side knobs (see module docstring for semantics)."""
    bits: int = 4                   # quantized-push width (fixed mode)
    threshold: float = 0.25         # push iff R > threshold * anchor; 0 = always
    anchor_decay: float = 0.9       # peak-envelope decay per round (fixed mode)
    max_staleness: int = 8          # skipped rounds tolerated before a resync
    wire_backend: object = "reference"   # name or WireBackend instance
    bit_schedule: Optional[BitSchedule] = None  # rel-mode schedule: adaptive width

    def validate(self) -> "PublishConfig":
        assert self.bits in (1, 2, 4, 8), self.bits
        assert self.threshold >= 0.0, self.threshold
        assert 0.0 < self.anchor_decay <= 1.0, self.anchor_decay
        assert self.max_staleness >= 0, self.max_staleness
        if self.bit_schedule is not None:
            self.bit_schedule.validate()
            assert self.bit_schedule.adaptive, \
                "constant schedules belong in PublishConfig.bits"
            assert self.bit_schedule.threshold_mode == "rel", \
                "the publisher anchor is the rel-mode anchor; abs-threshold " \
                "schedules have no shared anchor to reuse"
        return self


class PublisherState(NamedTuple):
    """Trainer-side publishing state (host-side; pytrees hold device arrays)."""
    theta_pub: Pytree           # the fleet's dequantize-accumulated view (f32)
    R_anchor: jax.Array         # decaying peak envelope A^k (f32 scalar)
    rounds_behind: int = 0      # consecutive rounds since the last message
    seq: int = 0                # publisher round counter
    n_pushes: int = 0           # quantized delta pushes sent
    n_resyncs: int = 0          # full-precision resyncs sent
    bits_sent: float = 0.0      # cumulative wire bits (analytic accounting)


class DeltaMsg(NamedTuple):
    """One quantized parameter-delta push (per-leaf packed payload)."""
    seq: int                    # publisher round this delta was cut at
    width: int                  # quantization bits b (the width sidecar)
    bits: float                 # analytic wire cost of this message
    payloads: list              # per-leaf packed uint8 codes (wire spec §3)
    radii: list                 # per-leaf f32 scalar radii (wire spec §1)


class ResyncMsg(NamedTuple):
    """Full-precision resync: raw f32 parameters (bounded-staleness escape)."""
    seq: int
    bits: float
    params: Pytree


class ReplicaState(NamedTuple):
    """One inference replica's serving weights + freshness bookkeeping."""
    params: Pytree              # serving weights (f32)
    rounds_behind: int = 0      # rounds since the last applied message
    seq: int = -1               # seq of the last applied message
    n_applied: int = 0
    n_resyncs: int = 0


def _f32_copy(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), tree)


def init_publisher(params: Pytree, cfg: PublishConfig) -> PublisherState:
    """Publisher with the fleet bootstrapped at an exact snapshot of
    ``params`` — the initial full-precision sync is accounted at
    ``dense_bits(p)`` (every policy pays it, so byte comparisons stay
    honest)."""
    cfg.validate()
    return PublisherState(theta_pub=_f32_copy(params),
                          R_anchor=jnp.zeros((), jnp.float32),
                          bits_sent=float(dense_bits(tree_size(params))))


def init_replica(snapshot: Pytree) -> ReplicaState:
    """Replica joining the fleet from a full-precision snapshot (the same
    snapshot the publisher's ``theta_pub`` started from, or a later
    :class:`ResyncMsg` payload)."""
    return ReplicaState(params=_f32_copy(snapshot))


def _leaf_radii(backend, g_leaves, q_leaves):
    """Pass 1, streamed: one scalar innovation radius per leaf (the fused
    backend's absmax kernel / the reference max-abs, via the backend's own
    ``innovation`` on a single-leaf tree — no whole-model diff is ever
    materialized)."""
    radii = []
    for g, q in zip(g_leaves, q_leaves):
        if g.size == 0:
            radii.append(jnp.zeros((), jnp.float32))
            continue
        _, _, R = backend.innovation(g, q, per_leaf=True)
        radii.append(R)
    return radii


def publish(cfg: PublishConfig, state: PublisherState,
            params: Pytree):
    """One publisher round against the current trainer ``params``.

    Returns ``(msg, new_state)`` where ``msg`` is ``None`` (lazy skip), a
    :class:`DeltaMsg` (quantized push) or a :class:`ResyncMsg`
    (full-precision bounded-staleness escape).  Decision order:

    1. ``R == 0`` — the published view already equals the parameters:
       skip (and never resync; there is nothing to say).
    2. ``threshold == 0`` or ``R > threshold * A`` — quantized push.
    3. ``rounds_behind + 1 > max_staleness`` — full resync.
    4. otherwise — skip (``rounds_behind`` grows).
    """
    cfg.validate()
    backend = get_backend(cfg.wire_backend)
    g_leaves, treedef = jax.tree_util.tree_flatten(params)
    q_leaves = jax.tree_util.tree_leaves(state.theta_pub)
    radii = _leaf_radii(backend, g_leaves, q_leaves)
    R_max = (jnp.max(jnp.stack(radii)) if radii
             else jnp.zeros((), jnp.float32))
    p = tree_size(params)
    n_leaves = len(g_leaves)

    # anchor + width: the BitSchedule rel-anchor machinery.  Adaptive mode
    # routes through select_bits itself (shared anchor, budget-aware);
    # fixed mode maintains the identical peak-envelope expression.
    if cfg.bit_schedule is not None:
        b_sel, _, anchor_new = select_bits(
            cfg.bit_schedule, R_max, state.bits_sent, state.seq, p,
            n_radii=n_leaves, R_anchor=state.R_anchor)
        width = int(b_sel)
    else:
        width = cfg.bits
        anchor_new = jnp.maximum(R_max, cfg.anchor_decay * state.R_anchor)

    Rm, A = float(R_max), float(anchor_new)
    base = state._replace(R_anchor=anchor_new, seq=state.seq + 1)

    if Rm == 0.0:
        return None, base._replace(rounds_behind=state.rounds_behind + 1)

    if cfg.threshold == 0.0 or Rm > cfg.threshold * A:
        # pass 2, streamed: per leaf, quantize -> pack -> q_new before the
        # next leaf is touched (payload layout is the backend's; byte
        # semantics are the wire spec's)
        qn_leaves, payloads, radii_out = [], [], []
        for g, q in zip(g_leaves, q_leaves):
            if g.size == 0:
                qn_leaves.append(jnp.zeros(g.shape, jnp.float32))
                payloads.append(jnp.zeros((0,), jnp.uint8))
                radii_out.append(jnp.zeros((), jnp.float32))
                continue
            rt = backend.roundtrip(g, q, width, per_leaf=True,
                                   with_payload=True)
            qn_leaves.append(rt.q_new)
            payloads.append(rt.payload[0])
            radii_out.append(rt.R_tree)
        bits = float(upload_bits(p, width, n_radii=n_leaves,
                                 bit_sidecar=cfg.bit_schedule is not None))
        msg = DeltaMsg(seq=state.seq, width=width, bits=bits,
                       payloads=payloads, radii=radii_out)
        return msg, base._replace(
            theta_pub=jax.tree_util.tree_unflatten(treedef, qn_leaves),
            rounds_behind=0, n_pushes=state.n_pushes + 1,
            bits_sent=state.bits_sent + bits)

    if state.rounds_behind + 1 > cfg.max_staleness:
        bits = float(dense_bits(p))
        msg = ResyncMsg(seq=state.seq, bits=bits, params=_f32_copy(params))
        return msg, base._replace(
            theta_pub=_f32_copy(params), rounds_behind=0,
            n_resyncs=state.n_resyncs + 1,
            bits_sent=state.bits_sent + bits)

    return None, base._replace(rounds_behind=state.rounds_behind + 1)


def apply_message(state: ReplicaState, msg,
                  cfg: PublishConfig) -> ReplicaState:
    """Replica side: dequantize-accumulate a :class:`DeltaMsg` into the
    serving weights (per-leaf streamed, bitwise equal to the publisher's
    ``theta_pub`` recursion), install a :class:`ResyncMsg` snapshot
    verbatim, or age one round on ``None``."""
    if msg is None:
        return state._replace(rounds_behind=state.rounds_behind + 1)
    if isinstance(msg, ResyncMsg):
        return ReplicaState(params=_f32_copy(msg.params), rounds_behind=0,
                            seq=msg.seq, n_applied=state.n_applied + 1,
                            n_resyncs=state.n_resyncs + 1)
    leaves, treedef = jax.tree_util.tree_flatten(state.params)
    new_leaves = []
    for leaf, payload, R in zip(leaves, msg.payloads, msg.radii):
        if leaf.size == 0:
            new_leaves.append(leaf)
            continue
        # payloads may be pad-extended (cpb / Pallas BLOCK multiples);
        # codes are in order, so the first `size` are the real ones
        codes = unpack_codes(payload, msg.width)[:leaf.size]
        delta = delta_of_codes(codes, R, msg.width).reshape(leaf.shape)
        new_leaves.append(leaf + delta)
    return ReplicaState(params=jax.tree_util.tree_unflatten(treedef,
                                                            new_leaves),
                        rounds_behind=0, seq=msg.seq,
                        n_applied=state.n_applied + 1,
                        n_resyncs=state.n_resyncs)


def staleness_drift(params: Pytree, replica: ReplicaState) -> float:
    """Serving-freshness diagnostic: ``||theta - replica||_inf`` (the bound
    the lazy rule enforces on skipped rounds is ``threshold * A`` against
    the published view; see module docstring)."""
    return max(float(jnp.max(jnp.abs(jnp.asarray(g, jnp.float32) - r)))
               if g.size else 0.0
               for g, r in zip(jax.tree_util.tree_leaves(params),
                               jax.tree_util.tree_leaves(replica.params)))
