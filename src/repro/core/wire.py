"""Pluggable wire backends: one interface for the quantize/pack/dequant hot
path, with a ``reference`` jnp implementation and a ``fused`` two-pass
implementation.

Every consumer of the quantizer — ``worker_update`` (fixed and adaptive
paths), the simulated runner, the wire microbenchmark, and the packed
sharded wire in ``launch/train.py`` — routes through this interface, so the
kernel-level pipeline can be swapped without touching the LAQ state machine.
Selection is by name via ``StrategyConfig.wire_backend``:

* ``reference`` — the paper-faithful jnp path from :mod:`repro.core.quantize`
  (~5-6 full-gradient sweeps per round: diff+inf-norm, codes, delta, q_new,
  err_sq, innovation_sq as separate elementwise passes).
* ``fused`` — the two-pass pipeline: pass 1 reduces the radius
  ``R = ||grad - qhat||_inf`` blockwise without materializing the diff;
  pass 2 emits codes+payload, delta, q_new AND the per-block partial sums
  for ``||grad - q_new||^2`` / ``||delta||^2`` in a single sweep, so the
  skip-criterion inputs come for free.  Two lowerings of the same
  algorithm: compiled Pallas kernels (:mod:`repro.kernels`) off-CPU, and an
  op-for-op blocked jnp expression on CPU, where interpret-mode Pallas would
  serialize the grid (lowering="auto" picks per ``jax.default_backend()``;
  tests pin "pallas"/"jnp" explicitly).

Equivalence contract (asserted in tests/test_wire_backend.py over
{qgd, laq} x bits {2, 4, 8} x {global, per-leaf} radii): the wire content —
codes, radii, ``delta``, ``q_new`` — is **bit-identical** across backends
(the elementwise expressions are kept identical, down to association order),
and whole simulated LAQ runs reproduce bit-identical trajectories on either
backend.  The scalar moments ``err_sq``/``innovation_sq`` are reduced with
the same tree as the reference on the CPU jnp lowering (usually bit-equal),
but XLA may re-derive a fused producer inside a reduce with a different
mul-add contraction, and the Pallas lowering emits blockwise partial sums —
so moments are only guaranteed to float32 reduction accuracy (~1e-7
relative), which the skip criterion's O(1) threshold margins tolerate.

The byte-level wire layout both backends emit is specified normatively in
``docs/wire-format.md``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adaptive import (dequantize_dynamic, quantize_dynamic, tau_of_selection)
from .compressors import (_flat, _unflat, reference_sparse_quantize,
                          scatter_selection, select_support, sparse_grid)
from .quantize import (dequantize_innovation, innovation, pack_codes,
                       quantize_codes, roundtrip_parts, tau, tree_sq_norm)

Pytree = object


class WireRoundtrip(NamedTuple):
    """Everything one round's quantize step produces for one worker."""
    q_new: Pytree           # Q_m(theta^k) = qhat + delta
    delta: Pytree           # dequantized innovation deltaQ_m^k
    R_tree: Pytree          # per-leaf radii (global R replicated if not per-leaf)
    R_max: jax.Array        # max leaf radius (paper Fig. 3 diagnostic)
    err_sq: jax.Array       # ||grad - q_new||^2  (criterion eps term)
    innovation_sq: jax.Array  # ||delta||^2       (criterion LHS)
    payload: Optional[list]   # per-leaf packed uint8 codes (with_payload only);
                              # layout is backend-specific (the fused payload is
                              # BLOCK-padded), byte semantics are shared


class WireBackend:
    """Interface: radius reduction, quantize roundtrip, server dequant-acc.

    The per-LEAF primitives (``leaf_absmax`` / ``leaf_quantize`` /
    ``leaf_quantize_adaptive``) are the streamed sharded wire's hot loop
    (launch/train.py ``_packed_aggregate`` touches one leaf at a time).
    Their base-class bodies below ARE the reference expressions — verbatim
    :mod:`repro.core.quantize` / :mod:`repro.core.adaptive` calls — so every
    backend inherits bit-identical wire content by code sharing; subclasses
    override only to swap the *lowering* (the fused backend dispatches the
    Pallas kernels off-CPU).
    """

    name = "?"

    def innovation(self, grad: Pytree, qhat: Pytree, per_leaf: bool = False):
        """``(diff, R_tree, R_max)`` — same contract as quantize.innovation."""
        raise NotImplementedError

    def roundtrip(self, grad: Pytree, qhat: Pytree, bits: int,
                  per_leaf: bool = False,
                  with_payload: bool = False) -> WireRoundtrip:
        raise NotImplementedError

    def leaf_absmax(self, g, qh):
        """Scalar ``|| g - qh ||_inf`` for ONE leaf (f32) — the radius
        pre-pass primitive (pass 1 of the two-pass pipeline, per leaf).
        Mirrors ``innovation``/``tree_inf_norm`` exactly; empty leaves
        reduce to 0 like the tree helpers skip them."""
        if g.size == 0:
            return jnp.zeros((), jnp.float32)
        return jnp.max(jnp.abs(g.astype(jnp.float32)
                               - qh.astype(jnp.float32))).astype(jnp.float32)

    def leaf_quantize(self, g, qh, R, bits: int):
        """``(codes, delta)`` for one leaf at one static width — the
        send-side pass-2 sweep of the streamed sharded wire.  Shape
        preserving (codes uint8, delta f32, both leaf-shaped): the
        axis-packed payload codec downstream packs along the leaf's last
        dim, so the codes must keep the leaf shape."""
        d = g.astype(jnp.float32) - qh.astype(jnp.float32)
        codes = quantize_codes(d, R, bits)
        delta = dequantize_innovation(codes, R, bits)
        return codes, delta

    def leaf_quantize_adaptive(self, g, qh, R, grid, onehot, t_sel):
        """Traced-width variant of :meth:`leaf_quantize`: ``onehot``
        selects from the static ascending ``grid``, ``t_sel`` is
        ``tau_of_selection(grid, onehot)`` (computed once per round by the
        caller, not per leaf)."""
        d = g.astype(jnp.float32) - qh.astype(jnp.float32)
        codes = quantize_dynamic(d, R, grid, onehot)
        delta = dequantize_dynamic(codes, R, t_sel)
        return codes, delta

    def adaptive_roundtrip(self, grad: Pytree, qhat: Pytree, diff: Pytree,
                           R_tree: Pytree, grid, onehot):
        """Dynamic-width roundtrip ``(q_new, delta, err_sq, innovation_sq)``
        for the width encoded in ``onehot`` over the static ``grid``.

        ``diff``/``R_tree`` come from this backend's own prior
        :meth:`innovation` call — the width selection (adaptive.select_bits)
        needs the radius BEFORE the quantize sweep can run, so the two
        passes cannot be fused across that data dependence.  The base body
        is the reference staged pipeline moved verbatim from
        ``strategy.worker_update`` (bit-compatibility anchor); the fused
        backend overrides with the width-grid-unrolled pass-2 kernel that
        emits delta, q_new and both criterion moments in one sweep.
        """
        codes = quantize_dynamic(diff, R_tree, grid, onehot)
        delta = dequantize_dynamic(codes, R_tree,
                                   tau_of_selection(grid, onehot))
        q_new = jax.tree.map(lambda q, d: q.astype(jnp.float32) + d,
                             qhat, delta)
        err_sq = tree_sq_norm(jax.tree.map(
            lambda g, qn: g.astype(jnp.float32) - qn, grad, q_new))
        innovation_sq = tree_sq_norm(delta)
        return q_new, delta, err_sq, innovation_sq

    def dequant_acc(self, packed, R, keep, bits: int, n: int, acc=None):
        """Server side: ``(acc +) sum_w keep_w * dequant(packed_w, R_w)``."""
        raise NotImplementedError

    def sparse_quantize(self, vals, lo, hi, bits: int):
        """Quantize stage of the sparse pipeline on gathered values:
        ``(codes uint8 [k], deq f32 [k])`` via the sign-magnitude grid on
        [lo, hi] (core/compressors.py).  Everything around it — support
        selection, grid moments, scatter, payload packing — is shared code
        in :func:`sparse_roundtrip`, so backends only differ in this
        elementwise map and must match it bitwise."""
        raise NotImplementedError


class ReferenceWire(WireBackend):
    """The jnp path of core/quantize.py, verbatim (the tests' ground truth)."""

    name = "reference"

    def innovation(self, grad, qhat, per_leaf=False):
        return innovation(grad, qhat, per_leaf)

    def roundtrip(self, grad, qhat, bits, per_leaf=False, with_payload=False):
        qints, R_tree, delta, q_new, R_max, err_sq = roundtrip_parts(
            grad, qhat, bits, per_leaf)
        innovation_sq = tree_sq_norm(delta)
        payload = None
        if with_payload:
            cpb = 8 // bits
            mid = jnp.uint8((2 ** bits) // 2)

            def leaf_payload(q):
                flat = q.reshape(-1)
                pad = (-flat.shape[0]) % cpb
                if pad:
                    flat = jnp.concatenate([flat, jnp.full((pad,), mid,
                                                           jnp.uint8)])
                return pack_codes(flat, bits)

            payload = [leaf_payload(q) for q in jax.tree_util.tree_leaves(qints)]
        return WireRoundtrip(q_new, delta, R_tree, R_max, err_sq,
                             innovation_sq, payload)

    def dequant_acc(self, packed, R, keep, bits, n, acc=None):
        from repro.kernels.ref import dequant_acc_ref
        return dequant_acc_ref(packed, R.astype(jnp.float32),
                               keep.astype(jnp.float32), bits, n, acc)

    def sparse_quantize(self, vals, lo, hi, bits):
        return reference_sparse_quantize(vals, lo, hi, bits)


def _fused_leaf_jnp(g, qh, R, bits, with_payload):
    """Op-for-op jnp lowering of the pass-2 kernel, on the dense flat leaf.

    Padding and block tiling belong to the Pallas lowering only: a jnp
    moment reduce fused with a slice-of-padded-array lowers to a masked
    wide reduction whose partial-sum grouping differs from the reference's
    dense reduce at the last ulp — enough to flip near-tie skip decisions.
    Dense flat arrays give both backends the identical elementwise
    expressions AND the identical reduction tree, so wire content and
    moments are bit-identical on CPU.
    """
    n = g.size
    gf = g.reshape(-1).astype(jnp.float32)
    qf = qh.reshape(-1).astype(jnp.float32)
    d = gf - qf
    t = tau(bits)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.clip(jnp.floor((d + R) / denom + 0.5), 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    qn = qf + delta
    err = gf - qn
    err_sq = jnp.sum(err * err)
    inn_sq = jnp.sum(delta * delta)
    payload = None
    if with_payload:
        cpb = 8 // bits
        pad = (-n) % cpb
        qp = q
        if pad:
            qp = jnp.concatenate(
                [q, jnp.full((pad,), (levels + 1) // 2, jnp.uint8)])
        payload = pack_codes(qp, bits)
    return delta, qn, err_sq, inn_sq, payload


def _fused_leaf_adaptive_jnp(g, qh, R, grid, onehot, t_sel,
                             with_payload=False):
    """Adaptive (traced-width) analogue of :func:`_fused_leaf_jnp`: the
    whole pass-2 sweep — grid-evaluated codes, delta, q_new and both moments
    — as one dense flat per-leaf expression.  The code/delta math is
    expression-for-expression ``quantize_dynamic`` + ``dequantize_dynamic``
    (via the shared ``quantize_codes``), so wire content and moments are
    bit-identical to the reference staged path on CPU.  The payload (wanted
    by the wire microbench's pass framing only) is packed at the provision
    width max(grid), matching the adaptive Pallas kernel."""
    n = g.size
    gf = g.reshape(-1).astype(jnp.float32)
    qf = qh.reshape(-1).astype(jnp.float32)
    d = gf - qf
    q = None
    for i, b in enumerate(grid):
        qi = quantize_codes(d, R, b)
        q = qi if q is None else jnp.where(onehot[i] > 0, qi, q)
    delta = 2.0 * t_sel * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    qn = qf + delta
    err = gf - qn
    payload = None
    if with_payload:
        provision = max(grid)
        pad = (-n) % (8 // provision)
        qp = q
        if pad:
            qp = jnp.concatenate([q, jnp.zeros((pad,), jnp.uint8)])
        payload = pack_codes(qp, provision)
    return delta, qn, jnp.sum(err * err), jnp.sum(delta * delta), payload


class FusedWire(WireBackend):
    """The two-pass fused pipeline (see module docstring).

    ``lowering``: "auto" (Pallas off-CPU, blocked jnp on CPU), "pallas"
    (force the kernels — interpret mode on CPU; the test configuration), or
    "jnp" (force the blocked jnp expression).
    """

    name = "fused"

    def __init__(self, lowering: str = "auto"):
        assert lowering in ("auto", "pallas", "jnp"), lowering
        self.lowering = lowering

    def _use_pallas(self) -> bool:
        if self.lowering == "auto":
            return jax.default_backend() != "cpu"
        return self.lowering == "pallas"

    def leaf_absmax(self, g, qh):
        if g.size and self._use_pallas():
            from repro.kernels import absmax
            return absmax(g, qh)
        return super().leaf_absmax(g, qh)

    def leaf_quantize(self, g, qh, R, bits):
        if g.size and self._use_pallas():
            from repro.kernels import quantize_codes_fused
            codes, delta = quantize_codes_fused(g, qh, R, bits)
            return codes.reshape(g.shape), delta.reshape(g.shape)
        # the dense jnp expressions of the base class ARE the pass-2 math
        # (codes + delta, one sweep under jit) — bit-identical by sharing
        return super().leaf_quantize(g, qh, R, bits)

    def leaf_quantize_adaptive(self, g, qh, R, grid, onehot, t_sel):
        if g.size and self._use_pallas():
            from repro.kernels import quantize_codes_adaptive
            codes, delta = quantize_codes_adaptive(g, qh, R, onehot,
                                                   tuple(grid))
            return codes.reshape(g.shape), delta.reshape(g.shape)
        return super().leaf_quantize_adaptive(g, qh, R, grid, onehot, t_sel)

    def _radii(self, g_leaves, q_leaves, per_leaf):
        maxes = [self.leaf_absmax(g, qh) for g, qh in zip(g_leaves, q_leaves)]
        if per_leaf:
            return maxes, jnp.max(jnp.stack(maxes))
        R = jnp.max(jnp.stack([m for m, g in zip(maxes, g_leaves) if g.size]
                              or [jnp.zeros((), jnp.float32)]))
        return [R for _ in g_leaves], R

    def innovation(self, grad, qhat, per_leaf=False):
        """Radius via the pass-1 absmax reduction; the diff itself stays a
        lazy elementwise expression for downstream consumers (the adaptive
        quantizer), so no extra full-gradient sweep is spent on it here."""
        diff = jax.tree.map(
            lambda g, q: g.astype(jnp.float32) - q.astype(jnp.float32),
            grad, qhat)
        g_leaves, treedef = jax.tree_util.tree_flatten(grad)
        q_leaves = jax.tree_util.tree_leaves(qhat)
        R_leaves, R_max = self._radii(g_leaves, q_leaves, per_leaf)
        R_tree = jax.tree_util.tree_unflatten(treedef, R_leaves)
        return diff, R_tree, R_max

    def roundtrip(self, grad, qhat, bits, per_leaf=False, with_payload=False):
        assert bits in (1, 2, 4, 8), \
            f"fused wire backend covers the packed-width grid, got bits={bits}"
        g_leaves, treedef = jax.tree_util.tree_flatten(grad)
        q_leaves = jax.tree_util.tree_leaves(qhat)
        R_leaves, R_max = self._radii(g_leaves, q_leaves, per_leaf)
        use_pallas = self._use_pallas()

        delta_leaves, qnew_leaves, payload = [], [], []
        err_parts, inn_parts = [], []
        for g, qh, R in zip(g_leaves, q_leaves, R_leaves):
            if g.size == 0:
                delta_leaves.append(jnp.zeros(g.shape, jnp.float32))
                qnew_leaves.append(jnp.zeros(g.shape, jnp.float32))
                if with_payload:
                    # keep the payload list leaf-aligned (one entry per leaf)
                    payload.append(jnp.zeros((0,), jnp.uint8))
                continue
            if use_pallas:
                from repro.kernels import quantize_pack_fused
                pk, dl, qn, esq, isq = quantize_pack_fused(g, qh, R, bits)
            else:
                dl, qn, esq, isq, pk = _fused_leaf_jnp(g, qh, R, bits,
                                                       with_payload)
            delta_leaves.append(dl.reshape(g.shape))
            qnew_leaves.append(qn.reshape(g.shape))
            err_parts.append(esq)
            inn_parts.append(isq)
            if with_payload:
                payload.append(pk)

        err_sq = (jnp.sum(jnp.stack(err_parts)) if err_parts
                  else jnp.zeros((), jnp.float32))
        inn_sq = (jnp.sum(jnp.stack(inn_parts)) if inn_parts
                  else jnp.zeros((), jnp.float32))
        return WireRoundtrip(
            q_new=jax.tree_util.tree_unflatten(treedef, qnew_leaves),
            delta=jax.tree_util.tree_unflatten(treedef, delta_leaves),
            R_tree=jax.tree_util.tree_unflatten(treedef, R_leaves),
            R_max=R_max, err_sq=err_sq, innovation_sq=inn_sq,
            payload=payload if with_payload else None)

    def adaptive_roundtrip(self, grad, qhat, diff, R_tree, grid, onehot):
        """Adaptive pass 2 as ONE sweep: the width-grid-unrolled fused
        kernel (kernels/quant_pack.py — one ``lax.switch`` arm per grid
        width, each arm the static-width pipeline) off-CPU, the dense flat
        jnp expression of the same sweep on CPU.  ``diff`` is deliberately
        unused here: innovation() keeps it a lazy elementwise expression,
        and this path recomputes g - qh inside the sweep instead of
        materializing the tensor."""
        grid = tuple(grid)
        assert all(b in (1, 2, 4, 8) for b in grid), \
            f"fused wire backend covers the packed-width grid, got {grid}"
        t_sel = tau_of_selection(grid, onehot)
        use_pallas = self._use_pallas()
        g_leaves, treedef = jax.tree_util.tree_flatten(grad)
        q_leaves = jax.tree_util.tree_leaves(qhat)
        R_leaves = jax.tree_util.tree_leaves(R_tree)

        delta_leaves, qnew_leaves, err_parts, inn_parts = [], [], [], []
        for g, qh, R in zip(g_leaves, q_leaves, R_leaves):
            if g.size == 0:
                delta_leaves.append(jnp.zeros(g.shape, jnp.float32))
                qnew_leaves.append(jnp.zeros(g.shape, jnp.float32))
                continue
            if use_pallas:
                from repro.kernels import quantize_pack_adaptive
                _, dl, qn, esq, isq = quantize_pack_adaptive(
                    g, qh, R, onehot, grid)
            else:
                dl, qn, esq, isq, _ = _fused_leaf_adaptive_jnp(
                    g, qh, R, grid, onehot, t_sel)
            delta_leaves.append(dl.reshape(g.shape))
            qnew_leaves.append(qn.reshape(g.shape))
            err_parts.append(esq)
            inn_parts.append(isq)

        err_sq = (jnp.sum(jnp.stack(err_parts)) if err_parts
                  else jnp.zeros((), jnp.float32))
        inn_sq = (jnp.sum(jnp.stack(inn_parts)) if inn_parts
                  else jnp.zeros((), jnp.float32))
        return (jax.tree_util.tree_unflatten(treedef, qnew_leaves),
                jax.tree_util.tree_unflatten(treedef, delta_leaves),
                err_sq, inn_sq)

    def dequant_acc(self, packed, R, keep, bits, n, acc=None):
        if self._use_pallas():
            from repro.kernels import dequant_acc
            return dequant_acc(packed, R, keep, bits, n, acc)
        from repro.kernels.ref import dequant_acc_ref
        return dequant_acc_ref(packed, R.astype(jnp.float32),
                               keep.astype(jnp.float32), bits, n, acc)

    def sparse_quantize(self, vals, lo, hi, bits):
        if vals.size == 0:
            return (jnp.zeros((0,), jnp.uint8), jnp.zeros((0,), jnp.float32))
        if self._use_pallas():
            from repro.kernels import sparse_quantize_pack
            _, codes, deq = sparse_quantize_pack(vals, lo, hi, bits)
            return codes, deq
        # blocked-jnp lowering: the gathered values vector is dense and
        # flat, so the op-for-op expressions ARE the reference's — wire
        # content is bit-identical on CPU by construction
        return reference_sparse_quantize(vals, lo, hi, bits)


_BACKENDS = {
    "reference": ReferenceWire(),
    "fused": FusedWire(),
}


def get_backend(name) -> WireBackend:
    """Resolve a backend by name (or pass a WireBackend instance through —
    tests use that to pin the fused lowering)."""
    if isinstance(name, WireBackend):
        return name
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire backend {name!r}; have {sorted(_BACKENDS)}") from None


# ---------------------------------------------------------------------------
# Sparse wire roundtrip — the EF-LAQ compressor pipeline's integration
# point (core/compressors.py supplies the stages; worker_update calls
# this).  Selection, scatter, moments and payload packing are SHARED code;
# only the quantize stage's elementwise map routes through the backend, so
# the bit-identity contract of the dense wire extends to the sparse one.
# ---------------------------------------------------------------------------

class SparseRoundtrip(NamedTuple):
    """One worker's sparse quantize step (mirrors :class:`WireRoundtrip`
    plus the sparse payload halves)."""
    q_new: Pytree           # qhat + delta (dense-shaped)
    delta: Pytree           # sparse-valued dequantized innovation
    lo: jax.Array           # magnitude-grid floor sidecar (f32 scalar)
    R: jax.Array            # magnitude-grid ceiling sidecar (max |survivor|)
    err_sq: jax.Array       # support-restricted quantization error (see below)
    innovation_sq: jax.Array  # ||delta||^2 (criterion LHS)
    idx: jax.Array          # int32 [k] sorted support (the index payload)
    codes: jax.Array        # uint8 [k] b-bit codes (pre-packing)
    payload: Optional[jax.Array]  # packed uint8 code bytes (with_payload only)


def sparse_roundtrip(backend, grad: Pytree, qhat: Pytree, bits: int, k: int,
                     mode: str, key=None,
                     with_payload: bool = False) -> SparseRoundtrip:
    """Sparsify-then-quantize roundtrip over the flattened innovation.

    ``grad`` is the (EF-corrected) gradient ``g_eff``; the innovation
    ``d = g_eff - qhat`` is flattened over the pytree, ``k`` coordinates
    survive (``mode``: "topk" / "randk", ``key`` for randk), the survivors
    are quantized on the sign-magnitude b-bit grid over ``[lo, hi]``
    (core/compressors.py — contractive on the survivor range, which the
    EF recursion requires; the dense wire's zero-less grid is not), and
    the receiver's dense view is scattered back.  Two f32 sidecars ``(lo,
    hi)`` — the per-leaf radius bucketing of the dense wire does not apply
    (the support already concentrates the scale).

    ``err_sq`` is the **support-restricted** quantization error
    ``sum_{i in S} (d_i - deq_i)^2`` — the criterion's epsilon-hat moment.
    The dropped tail is deliberately NOT counted: it is the sparsifier's
    deferred mass (EF's residual re-injects it next round), not wire
    noise, and folding it into epsilon-hat blows up the 7a threshold's
    ``3(eps + eps_prev)`` term so far past the innovation that every
    worker skips forever after its first upload.
    """
    backend = get_backend(backend)
    gflat, meta = _flat(grad)
    qflat, _ = _flat(qhat)
    d = gflat - qflat
    sel = select_support(mode, d, k, key)
    lo, hi = sparse_grid(sel.vals, bits)
    codes, deq = backend.sparse_quantize(sel.vals, lo, hi, bits)
    delta_flat = scatter_selection(sel, deq, d.shape[0])
    qn_flat = qflat + delta_flat
    err = sel.vals - deq
    err_sq = jnp.sum(err * err)
    inn_sq = jnp.sum(delta_flat * delta_flat)
    payload = None
    if with_payload:
        cpb = 8 // bits
        mid = jnp.uint8((2 ** bits) // 2)
        pad = (-codes.shape[0]) % cpb
        cp = codes
        if pad:
            cp = jnp.concatenate([codes, jnp.full((pad,), mid, jnp.uint8)])
        payload = pack_codes(cp, bits)
    return SparseRoundtrip(q_new=_unflat(qn_flat, meta),
                           delta=_unflat(delta_flat, meta),
                           lo=lo, R=hi, err_sq=err_sq, innovation_sq=inn_sq,
                           idx=sel.idx, codes=codes, payload=payload)


# ---------------------------------------------------------------------------
# Code-space inverse maps — recover the integer wire codes from a
# dequantized leaf and re-emit after mutating them.  Used by the fault
# layer (core/faults.py: MSB flips on the packed codes) and usable by any
# consumer that needs to edit a payload without re-running the quantizer.
# Exact on the emit path's own output: ``delta = 2 tau R q - R`` is
# recovered by rounding ``(delta + R) / (2 tau R)`` — the float32 rounding
# noise of the forward map is orders of magnitude below the half-step the
# round absorbs (codes are <= 255).
# ---------------------------------------------------------------------------

def codes_of_delta(delta: jax.Array, R, bits: int) -> jax.Array:
    """Inverse of the dequant map on one leaf: uint8 codes from ``delta``.

    ``R == 0`` emits the midpoint code, matching the forward map's
    convention for an identically-zero innovation.
    """
    t = tau(bits)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.round((delta.astype(jnp.float32) + R) / denom)
    q = jnp.clip(q, 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q))
    return q.astype(jnp.uint8)


def delta_of_codes(codes: jax.Array, R, bits: int) -> jax.Array:
    """Re-emit the dequantized leaf from (possibly mutated) codes — the
    same expression as quantize.dequantize_innovation, per leaf."""
    t = tau(bits)
    d = 2.0 * t * R * codes.astype(jnp.float32) - R
    return jnp.where(R > 0, d, jnp.zeros_like(d))


# ---------------------------------------------------------------------------
# Axis-packed wire payload helpers — the sharded collective wire format
# shared by launch/train.py (pack along the LAST dim: flattening a
# model-sharded leaf would force GSPMD to regather it).  Same
# little-end-first byte semantics as pack_codes / the Pallas kernels.
# ---------------------------------------------------------------------------

def axis_packable(q, bits: int) -> bool:
    cpb = 8 // bits
    return cpb > 1 and q.ndim >= 1 and q.shape[-1] % cpb == 0


def pack_codes_along_axis(q, bits: int):
    """Pack 8/b codes per byte along the last dim (no-op layout for b=8 or
    an indivisible last dim: raw uint8 codes ship unpacked)."""
    if not axis_packable(q, bits):
        return q
    cpb = 8 // bits
    parts = q.reshape(q.shape[:-1] + (q.shape[-1] // cpb, cpb))
    acc = parts[..., 0]
    for j in range(1, cpb):
        acc = acc | (parts[..., j] << (bits * j))
    return acc.astype(jnp.uint8)


def unpack_codes_along_axis(payload, bits: int, orig):
    """Inverse of :func:`pack_codes_along_axis`; ``orig`` supplies the
    unpacked shape (and whether packing applied at all)."""
    if not axis_packable(orig, bits):
        return payload
    cpb = 8 // bits
    mask = (1 << bits) - 1
    parts = [(payload >> (bits * j)) & mask for j in range(cpb)]
    return jnp.stack(parts, axis=-1).reshape(orig.shape)
