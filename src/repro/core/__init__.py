"""LAQ core: the paper's contribution as composable JAX modules.

Public API:
    StrategyConfig, CriterionConfig      -- configuration
    init_comm_state, aggregate, finalize_step, worker_update
                                         -- the LAQ state machine
    quantize_innovation / dequantize_innovation / quantize_roundtrip
                                         -- paper eq. (5)-(6)
    BitSchedule / select_bits            -- adaptive bit-width (A-LAQ)
    WireBackend / get_backend            -- pluggable quantize pipeline
                                            (reference jnp vs fused 2-pass)
    run_gradient_based / run_stochastic  -- simulated M-worker cluster
"""
from .adaptive import (BitSchedule, adaptive_roundtrip, grid_costs,
                       select_bits)
from .criterion import CriterionConfig, rhs_threshold, should_skip, push_history
from .quantize import (dense_bits, dequantize_innovation, pack_codes,
                       pack_nibbles, quantize_innovation, quantize_roundtrip,
                       tau, tree_inf_norm, tree_size, tree_sq_norm,
                       unpack_codes, unpack_nibbles, upload_bits)
from .strategy import (KINDS, CommState, RoundMetrics, StrategyConfig,
                       aggregate, finalize_step, init_comm_state, worker_update)
from .wire import (FusedWire, ReferenceWire, WireBackend, WireRoundtrip,
                   get_backend)
from .compressors import qsgd_compress, ssgd_compress
from .simulated import RunResult, run_gradient_based, run_stochastic
