"""LAQ core: the paper's contribution as composable JAX modules.

Public API:
    StrategyConfig, CriterionConfig      -- configuration
    init_comm_state, aggregate, finalize_step, worker_update
                                         -- the LAQ state machine
    quantize_innovation / dequantize_innovation / quantize_roundtrip
                                         -- paper eq. (5)-(6)
    LasgConfig / LazyState / should_skip_rule
                                         -- variance-aware lazy rules
                                            (LASG-WK / LASG-WK2 / LASG-PS;
                                            selected via
                                            StrategyConfig.lazy_rule)
    SvrgState                            -- variance-reduced local gradients
                                            (StrategyConfig.grad_mode="svrg")
    BitSchedule / select_bits            -- adaptive bit-width (A-LAQ;
                                            "rel" mode = scale-free
                                            bootstrap-anchored thresholds)
    EtaSchedule / eta_at                 -- per-round stepsize schedules
                                            (constant / inv_t / halving)
    WireBackend / get_backend            -- pluggable quantize pipeline
                                            (reference jnp vs fused 2-pass)
    CompressorPipeline / make_compressor -- composable sparsify->quantize->
                                            pack stages (top-k / rand-k;
                                            StrategyConfig.compressor) with
                                            optional error feedback
                                            (ErrorState; EF-LAQ)
    RoundEngine / GradientSource stages  -- the unified round engine
                                            (core/engine.py): FullBatchSource
                                            / MinibatchSource gradients,
                                            participation models (full /
                                            bernoulli / fixed_k sampling /
                                            markov churn / bounded-delay
                                            async) via
                                            StrategyConfig.participation
    FaultConfig                          -- fault injection (core/faults.py):
                                            payload corruption / wire
                                            bit-flips / crash-restart via
                                            StrategyConfig.faults
    DefenseConfig / DefenseState / run_with_watchdog
                                         -- fault-tolerant aggregation
                                            (core/defense.py): upload
                                            validation, norm-clipping,
                                            robust aggregators
                                            (StrategyConfig.aggregator),
                                            divergence watchdog rollback
    run_gradient_based / run_stochastic  -- simulated M-worker cluster
                                            (thin wrappers over RoundEngine;
                                            stochastic kinds: sgd/qsgd/ssgd/
                                            slaq/slaq_wk/slaq_wk2/slaq_ps)
    PublishConfig / publish / ReplicaState
                                         -- lazy-replica serving
                                            (core/replica.py): quantized
                                            parameter-delta publishing to an
                                            inference fleet with bounded
                                            staleness + forced resync
"""
from .adaptive import (BitSchedule, EtaSchedule, adaptive_roundtrip, eta_at,
                       grid_costs, select_bits)
from .criterion import (CriterionConfig, history_threshold, push_history,
                        rhs_threshold, should_skip)
from .defense import (AGGREGATORS, DefenseConfig, DefenseState,
                      WatchdogConfig, defense_step, init_defense_state,
                      migrate_carry, robust_aggregate, run_with_watchdog)
from .faults import (CORRUPT_KINDS, FaultConfig, apply_crashes, bitflip_keys,
                     corrupt_grads, corruption_mask, crash_mask,
                     flip_wire_codes)
from .lazy_rules import (LAZY_RULES, LasgConfig, LazyState, init_lazy_state,
                         should_skip_rule, smoothness_sq, variance_update)
from .quantize import (dense_bits, dequantize_innovation, pack_codes,
                       pack_nibbles, quantize_innovation, quantize_roundtrip,
                       tau, tree_inf_norm, tree_size, tree_sq_norm,
                       unpack_codes, unpack_nibbles, upload_bits)
from .strategy import (KINDS, CommState, RoundMetrics, StrategyConfig,
                       SvrgState, WorkerOut, aggregate, finalize_step,
                       init_comm_state, init_svrg_state, worker_update)
from .wire import (FusedWire, ReferenceWire, SparseRoundtrip, WireBackend,
                   WireRoundtrip, get_backend, sparse_roundtrip)
from .compressors import (COMPRESSORS, CodePacker, Compressor,
                          CompressorPipeline, ErrorState, RandKSparsifier,
                          TopKSparsifier, UniformQuantizer, compressor_keys,
                          init_error_state, make_compressor, qsgd_compress,
                          reference_sparse_quantize, select_support,
                          ssgd_compress, static_k)
from .engine import (PARTICIPATION, AccumulatingSource, DelayedParticipation,
                     FullBatchSource, FullParticipation, MarkovParticipation,
                     MinibatchSource, RoundEngine, RunResult,
                     SampledParticipation, accumulate_loss_grads,
                     apply_svrg_exact, apply_svrg_streaming, broadcast_w,
                     make_participation, participation_mask,
                     stale_side_grads)
from .replica import (DeltaMsg, PublishConfig, PublisherState, ReplicaState,
                      ResyncMsg, apply_message, init_publisher, init_replica,
                      publish, staleness_drift)
from .simulated import run_gradient_based, run_stochastic
