"""Pallas TPU kernels for the LAQ wire hot loops (quantize+pack, unpack+
dequant+accumulate). ops.py: jit wrappers; ref.py: pure-jnp oracles."""
from .ops import dequant_acc, quantize_pack
