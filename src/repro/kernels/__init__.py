"""Pallas TPU kernels for the LAQ wire hot loops (absmax radius reduction;
fused quantize+pack with moment side-outputs — fixed-width and
width-grid-unrolled adaptive variants; unpacked codes+delta sweeps for the
streamed sharded wire; sparse-pipeline quantize+pack on gathered survivors;
unpack+dequant+accumulate).
ops.py: jit wrappers; ref.py: pure-jnp oracles."""
from .ops import (absmax, dequant_acc, quantize_codes_adaptive,
                  quantize_codes_fused, quantize_pack,
                  quantize_pack_adaptive, quantize_pack_fused,
                  sparse_quantize_pack)
