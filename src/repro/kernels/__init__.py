"""Pallas TPU kernels for the LAQ wire hot loops (absmax radius reduction;
fused quantize+pack with moment side-outputs; sparse-pipeline quantize+pack
on gathered survivors; unpack+dequant+accumulate).
ops.py: jit wrappers; ref.py: pure-jnp oracles."""
from .ops import (absmax, dequant_acc, quantize_pack, quantize_pack_fused,
                  sparse_quantize_pack)
