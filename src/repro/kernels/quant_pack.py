"""Pallas TPU kernels for the LAQ wire hot loops — the fused two-pass pipeline.

The per-step elementwise sweep over the full gradient (quantize -> pack on
the send side; unpack -> dequantize -> accumulate over W workers on the
server side) is the paper's compute hot spot — it touches every parameter
every iteration.  On TPU these are VPU (vector-unit) kernels: the win is
fusing the whole send-side pipeline into two VMEM-tiled passes instead of
XLA's multi-kernel materialization of the intermediate diff / code / float
tensors.

Sweep-count accounting (one worker, one round, p-dim gradient):

    reference (core/quantize.py jnp path)       fused (this module)
    1. diff = grad - qhat  (materialized)       1. absmax: R = ||grad-qhat||_inf
    2. R = ||diff||_inf                            (in-kernel diff, no tensor)
    3. codes = quantize(diff, R)                2. quantize_pack: codes+pack,
    4. delta = dequantize(codes, R)                delta, q_new, and per-block
    5. q_new = qhat + delta                        partial sums for
    6. err_sq = ||grad - q_new||^2                 ||grad-q_new||^2 and
    7. innovation_sq = ||delta||^2                 ||delta||^2 — all in one
       (~5-6 full-gradient sweeps, 2+             VMEM pass (side-outputs are
       materialized temporaries)                   one f32 per block)

so the skip-criterion inputs (err_sq / innovation_sq) come for free with the
wire payload instead of costing two extra sweeps, and the radius reduction
no longer needs a materialized diff tensor.  The receive side
(``dequant_acc``) additionally takes an optional ``acc`` operand so the
server recursion ``agg^k = agg^{k-1} + sum_m delta_m`` folds into the same
pass instead of a separate p-length add.

Tiling: flat vectors are processed in LANE-aligned blocks (multiples of
1024 floats = 8 sublanes x 128 lanes); bits=4 packs two codes per byte and
bits=2 four codes per byte, so the packed block is block*b/8 bytes.  All
shapes are padded upstream in ops.py; the moment side-outputs mask the pad
tail (pad codes dequantize to a *nonzero* midpoint delta, so an unmasked
sum would be wrong for non-BLOCK-multiple lengths).

Validated in interpret mode on CPU against kernels/ref.py and against the
pure-jnp fused lowering in core/wire.py (tests sweep shapes x bits x
radii); compiled lowering targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096          # f32 elements per grid step (16 KiB VMEM in, fits easily)


def _quant_codes(diff, R, bits):
    t = 1.0 / (2.0 ** bits - 1.0)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((diff + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    return jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)


def _pack_block(q, bits):
    if bits == 8:
        return q
    cpb = 8 // bits                          # codes per byte (2, 4 or 8)
    qs = q.reshape(-1, cpb)
    acc = qs[:, 0]
    for j in range(1, cpb):
        acc = acc | (qs[:, j] << (bits * j))
    return acc.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Pass 1: blockwise |grad - qhat| max reduction (no materialized diff).
# ---------------------------------------------------------------------------

def _absmax_kernel(g_ref, qh_ref, out_ref):
    d = g_ref[...] - qh_ref[...]
    out_ref[0] = jnp.max(jnp.abs(d))


def absmax_pallas(grad, qhat, *, interpret: bool = True):
    """grad, qhat: flat f32 [n] (n % BLOCK == 0).

    Returns per-block partial maxima f32 [n // BLOCK]; the final (tiny)
    reduction over blocks happens in the caller.  Zero-padding is safe: the
    pad diff is 0 and abs-max is >= 0.
    """
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // BLOCK,), jnp.float32),
        interpret=interpret,
    )(grad, qhat)


# ---------------------------------------------------------------------------
# Pass 2: quantize + pack + dequantized delta + q_new, with per-block moment
# side-outputs (the skip-criterion inputs).
# ---------------------------------------------------------------------------

def _quantize_pack_kernel(bits, n_valid, g_ref, qh_ref, R_ref, packed_ref,
                          delta_ref, qnew_ref, err_ref, inn_ref):
    R = R_ref[0]
    g = g_ref[...]
    qh = qh_ref[...]
    d = g - qh
    q = _quant_codes(d, R, bits)
    t = 1.0 / (2.0 ** bits - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    delta_ref[...] = delta
    # same association as the reference: q_new = qhat + delta, err = g - q_new
    qn = qh + delta
    qnew_ref[...] = qn
    idx = (jax.lax.broadcasted_iota(jnp.int32, (BLOCK, 1), 0).reshape(-1)
           + pl.program_id(0) * BLOCK)
    valid = (idx < n_valid).astype(jnp.float32)
    err = (g - qn) * valid
    err_ref[0] = jnp.sum(err * err)
    dv = delta * valid
    inn_ref[0] = jnp.sum(dv * dv)
    packed_ref[...] = _pack_block(q, bits)


def quantize_pack_pallas(grad, qhat, R, bits: int, n_valid: int, *,
                         interpret: bool = True):
    """grad, qhat: flat f32 [n] (n % BLOCK == 0), R: scalar f32 [1],
    n_valid: static count of real (non-pad) elements.

    Returns ``(packed uint8 [n*bits/8], delta f32 [n], q_new f32 [n],
    err_part f32 [n//BLOCK], inn_part f32 [n//BLOCK])`` — the partial sums
    are masked to the first ``n_valid`` elements; their block-order sum gives
    ||grad - q_new||^2 and ||delta||^2.
    """
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    assert bits in (1, 2, 4, 8), bits
    out_block = BLOCK * bits // 8
    grid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_quantize_pack_kernel, bits, n_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_block,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * bits // 8,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n // BLOCK,), jnp.float32),
            jax.ShapeDtypeStruct((n // BLOCK,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, qhat, R)


def _quantize_pack_payload_kernel(bits, g_ref, qh_ref, R_ref, packed_ref,
                                  delta_ref):
    R = R_ref[0]
    d = g_ref[...] - qh_ref[...]
    q = _quant_codes(d, R, bits)
    t = 1.0 / (2.0 ** bits - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta_ref[...] = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    packed_ref[...] = _pack_block(q, bits)


def quantize_pack_payload_pallas(grad, qhat, R, bits: int, *,
                                 interpret: bool = True):
    """Payload-only variant of the pass-2 kernel: packed codes + delta, no
    q_new/moment outputs — for callers that only ship the wire payload and
    should not pay the extra VMEM writes (benchmarks, the roundtrip tests).
    """
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    assert bits in (1, 2, 4, 8), bits
    out_block = BLOCK * bits // 8
    grid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_quantize_pack_payload_kernel, bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_block,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * bits // 8,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, qhat, R)


# ---------------------------------------------------------------------------
# Adaptive pass 2: width-grid-unrolled fused quantize + pack.  The traced
# per-worker width selection (core/adaptive.py select_bits) cannot
# specialize the kernel at trace time, so the kernel carries one
# ``lax.switch`` arm per grid width — each arm IS the static-width pass-2
# pipeline above, so a pinned selection reproduces the fixed-width kernel
# bit-for-bit.  The packed payload is provisioned at the static width
# max(grid) (codes < 2^b always fit; the sharded wire's provisioning
# convention, docs/wire-format.md), which keeps every arm's output shapes
# identical — the lax.switch requirement.
# ---------------------------------------------------------------------------


def _adaptive_arm(b, provision, g, qh, R, valid):
    """One grid width's pass-2 pipeline (the static kernel body, verbatim),
    packed at the provision width so all arms shape-match."""
    d = g - qh
    q = _quant_codes(d, R, b)
    t = 1.0 / (2.0 ** b - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    qn = qh + delta
    err = (g - qn) * valid
    dv = delta * valid
    return (_pack_block(q, provision), delta, qn,
            jnp.sum(err * err), jnp.sum(dv * dv))


def _quantize_pack_adaptive_kernel(grid, provision, n_valid, g_ref, qh_ref,
                                   R_ref, sel_ref, packed_ref, delta_ref,
                                   qnew_ref, err_ref, inn_ref):
    R = R_ref[0]
    sel = sel_ref[0]
    g = g_ref[...]
    qh = qh_ref[...]
    idx = (jax.lax.broadcasted_iota(jnp.int32, (BLOCK, 1), 0).reshape(-1)
           + pl.program_id(0) * BLOCK)
    valid = (idx < n_valid).astype(jnp.float32)
    arms = [functools.partial(_adaptive_arm, b, provision) for b in grid]
    packed, delta, qn, err, inn = jax.lax.switch(sel, arms, g, qh, R, valid)
    packed_ref[...] = packed
    delta_ref[...] = delta
    qnew_ref[...] = qn
    err_ref[0] = err
    inn_ref[0] = inn


def quantize_pack_adaptive_pallas(grad, qhat, R, sel, grid, n_valid: int, *,
                                  interpret: bool = True):
    """grad, qhat: flat f32 [n] (n % BLOCK == 0), R: f32 [1], sel: int32 [1]
    index into ``grid`` (the ascending static width grid), n_valid: static
    count of real elements.

    Returns ``(packed uint8 [n*max(grid)/8], delta f32 [n], q_new f32 [n],
    err_part f32 [n//BLOCK], inn_part f32 [n//BLOCK])`` — the payload is
    provisioned at max(grid) bits (static shape across arms); moments are
    pad-masked block partials exactly like the fixed-width kernel.
    """
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    grid = tuple(grid)
    assert all(b in (1, 2, 4, 8) for b in grid), grid
    provision = max(grid)
    out_block = BLOCK * provision // 8
    pgrid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_quantize_pack_adaptive_kernel, grid, provision,
                          n_valid),
        grid=pgrid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_block,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * provision // 8,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n // BLOCK,), jnp.float32),
            jax.ShapeDtypeStruct((n // BLOCK,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, qhat, R, sel)


# ---------------------------------------------------------------------------
# Sharded-wire pass 2: quantize emitting UNPACKED codes + delta in one
# sweep.  The packed collective wire packs along the leaf's LAST dim
# (core/wire.py pack_codes_along_axis — flattening a model-sharded leaf
# would force a GSPMD regather), so the kernel leaves packing to that
# shared axis codec and just fuses the code/delta math; the caller reshapes
# the flat outputs back to the leaf shape.  Fixed-width and width-switched
# (adaptive) variants.
# ---------------------------------------------------------------------------


def _quantize_codes_kernel(bits, g_ref, qh_ref, R_ref, codes_ref, delta_ref):
    R = R_ref[0]
    d = g_ref[...] - qh_ref[...]
    q = _quant_codes(d, R, bits)
    t = 1.0 / (2.0 ** bits - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    codes_ref[...] = q
    delta_ref[...] = jnp.where(R > 0, delta, jnp.zeros_like(delta))


def quantize_codes_pallas(grad, qhat, R, bits: int, *, interpret: bool = True):
    """grad, qhat: flat f32 [n] (n % BLOCK == 0), R: f32 [1].

    Returns ``(codes uint8 [n], delta f32 [n])`` — the sharded packed wire's
    send-side sweep (codes stay unpacked for the axis codec)."""
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    assert bits in (1, 2, 4, 8), bits
    return pl.pallas_call(
        functools.partial(_quantize_codes_kernel, bits),
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, qhat, R)


def _codes_arm(b, g, qh, R):
    d = g - qh
    q = _quant_codes(d, R, b)
    t = 1.0 / (2.0 ** b - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    return q, jnp.where(R > 0, delta, jnp.zeros_like(delta))


def _quantize_codes_adaptive_kernel(grid, g_ref, qh_ref, R_ref, sel_ref,
                                    codes_ref, delta_ref):
    R = R_ref[0]
    sel = sel_ref[0]
    arms = [functools.partial(_codes_arm, b) for b in grid]
    q, delta = jax.lax.switch(sel, arms, g_ref[...], qh_ref[...], R)
    codes_ref[...] = q
    delta_ref[...] = delta


def quantize_codes_adaptive_pallas(grad, qhat, R, sel, grid, *,
                                   interpret: bool = True):
    """Width-switched variant of :func:`quantize_codes_pallas` (``sel``:
    int32 [1] index into ``grid``)."""
    n = grad.shape[0]
    assert n % BLOCK == 0, n
    grid = tuple(grid)
    assert all(b in (1, 2, 4, 8) for b in grid), grid
    return pl.pallas_call(
        functools.partial(_quantize_codes_adaptive_kernel, grid),
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, qhat, R, sel)


# ---------------------------------------------------------------------------
# Sparse pipeline: quantize + pack the GATHERED survivor values of the
# EF-LAQ compressor (core/compressors.py).  The selection/scatter halves
# are gather-bound and stay in XLA; the elementwise sign-magnitude grid
# math on the k survivors mirrors core/compressors.py's
# reference_sparse_quantize op for op, so the sparse wire content matches
# the reference backend bitwise (core/wire.py sparse_roundtrip contract).
# Covers the full packed grid b in {1, 2, 4, 8} — 1-bit (pure scaled-sign)
# is the EF frontier's headline regime.
# ---------------------------------------------------------------------------

def _sparse_quant_pack_kernel(bits, vals_ref, lo_ref, hi_ref, packed_ref,
                              codes_ref, deq_ref):
    lo = lo_ref[0]
    hi = hi_ref[0]
    v = vals_ref[...]
    L = 2 ** (bits - 1) - 1              # magnitude levels above lo
    a = jnp.abs(v)
    neg = v < 0
    step = (hi - lo) / max(L, 1)
    safe = jnp.where(step > 0, step, 1.0)
    mag = jnp.clip(jnp.floor((a - lo) / safe + 0.5), 0, L)
    mag = jnp.where(step > 0, mag, jnp.zeros_like(mag)).astype(jnp.uint8)
    q = ((neg.astype(jnp.uint8) << (bits - 1)) | mag).astype(jnp.uint8)
    codes_ref[...] = q
    deq_ref[...] = (jnp.where(neg, -1.0, 1.0)
                    * (lo + mag.astype(jnp.float32) * step))
    packed_ref[...] = _pack_block(q, bits)


def sparse_quant_pack_pallas(vals, lo, hi, bits: int, *,
                             interpret: bool = True):
    """vals: gathered survivor values, flat f32 [n] (n % BLOCK == 0,
    zero-padded upstream), lo/hi: the grid-endpoint sidecars, f32 [1].

    Returns ``(packed uint8 [n*bits/8], codes uint8 [n], deq f32 [n])``;
    the caller slices the k real entries off (pad values quantize like any
    zero and are discarded — the shared payload packing in core/wire.py
    re-pads canonically).
    """
    n = vals.shape[0]
    assert n % BLOCK == 0, n
    assert bits in (1, 2, 4, 8), bits
    out_block = BLOCK * bits // 8
    grid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_sparse_quant_pack_kernel, bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_block,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * bits // 8,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(vals, lo, hi)


# ---------------------------------------------------------------------------
# Receive side: unpack + dequant + W-accumulate (+ optional server-aggregate
# fold-in).
# ---------------------------------------------------------------------------

def _dequant_acc_kernel(bits, W, has_acc, *refs):
    if has_acc:
        packed_ref, R_ref, keep_ref, acc_ref, out_ref = refs
        acc = acc_ref[...].astype(jnp.float32)
    else:
        packed_ref, R_ref, keep_ref, out_ref = refs
        acc = jnp.zeros(out_ref.shape, jnp.float32)
    t = 1.0 / (2.0 ** bits - 1.0)
    for w in range(W):                       # W is static & small (workers/pods)
        pk = packed_ref[w, :]
        if bits == 8:
            codes = pk.astype(jnp.float32)
        else:
            mask = (1 << bits) - 1
            parts = [((pk >> (bits * j)) & mask).astype(jnp.float32)
                     for j in range(8 // bits)]
            codes = jnp.stack(parts, axis=-1).reshape(-1)
        R = R_ref[w]
        delta = 2.0 * t * R * codes - R
        delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
        acc = acc + delta * keep_ref[w]
    out_ref[...] = acc


def dequant_acc_pallas(packed, R, keep, bits: int, n: int, acc=None, *,
                       interpret: bool = True):
    """packed: [W, n*bits/8] uint8; R, keep: [W] f32 -> f32 [n] (summed).

    ``acc`` (optional f32 [n], e.g. the server aggregate) is folded into the
    same pass: out = acc + sum_w delta_w.
    """
    assert bits in (1, 2, 4, 8), bits
    W, nbytes = packed.shape
    in_block = BLOCK * bits // 8
    assert nbytes % in_block == 0, (nbytes, in_block)
    grid = (nbytes // in_block,)
    in_specs = [
        pl.BlockSpec((W, in_block), lambda i: (0, i)),
        pl.BlockSpec((W,), lambda i: (0,)),
        pl.BlockSpec((W,), lambda i: (0,)),
    ]
    args = [packed, R, keep]
    if acc is not None:
        in_specs.append(pl.BlockSpec((BLOCK,), lambda i: (i,)))
        args.append(acc)
    return pl.pallas_call(
        functools.partial(_dequant_acc_kernel, bits, W, acc is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(*args)
