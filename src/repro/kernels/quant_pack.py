"""Pallas TPU kernels for the LAQ wire hot loops.

The per-step elementwise sweep over the full gradient (quantize -> pack on
the send side; unpack -> dequantize -> accumulate over W workers on the
server side) is the paper's compute hot spot — it touches every parameter
every iteration.  On TPU these are VPU (vector-unit) kernels: the win is
fusing quantize+pack (resp. unpack+dequant+W-accumulate) into one VMEM-tiled
pass instead of XLA's multi-kernel materialization of the intermediate code
and float tensors.

Tiling: flat vectors are processed in LANE-aligned blocks (multiples of
1024 floats = 8 sublanes x 128 lanes); bits=4 packs two codes per byte and
bits=2 four codes per byte, so the packed block is block*b/8 bytes.  All
shapes are padded upstream in ops.py.

Validated in interpret mode on CPU against kernels/ref.py (tests sweep
shapes x bits x dtypes); compiled lowering targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096          # f32 elements per grid step (16 KiB VMEM in, fits easily)


def _quant_codes(diff, R, bits):
    t = 1.0 / (2.0 ** bits - 1.0)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((diff + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    return jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)


def _quantize_pack_kernel(bits, diff_ref, R_ref, packed_ref, delta_ref):
    R = R_ref[0]
    d = diff_ref[...]
    q = _quant_codes(d, R, bits)
    t = 1.0 / (2.0 ** bits - 1.0)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta_ref[...] = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    if bits == 8:
        packed_ref[...] = q
    else:
        cpb = 8 // bits                      # codes per byte (2 or 4)
        qs = q.reshape(-1, cpb)
        acc = qs[:, 0]
        for j in range(1, cpb):
            acc = acc | (qs[:, j] << (bits * j))
        packed_ref[...] = acc.astype(jnp.uint8)


def quantize_pack_pallas(diff, R, bits: int, *, interpret: bool = True):
    """diff: flat f32 [n] (n % BLOCK == 0), R: scalar f32 [1].

    Returns (packed uint8 [n*bits/8], delta f32 [n]).
    """
    n = diff.shape[0]
    assert n % BLOCK == 0, n
    assert bits in (2, 4, 8), bits
    out_block = BLOCK * bits // 8
    grid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_quantize_pack_kernel, bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_block,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * bits // 8,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(diff, R)


def _dequant_acc_kernel(bits, W, packed_ref, R_ref, keep_ref, out_ref):
    t = 1.0 / (2.0 ** bits - 1.0)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for w in range(W):                       # W is static & small (workers/pods)
        pk = packed_ref[w, :]
        if bits == 8:
            codes = pk.astype(jnp.float32)
        else:
            mask = (1 << bits) - 1
            parts = [((pk >> (bits * j)) & mask).astype(jnp.float32)
                     for j in range(8 // bits)]
            codes = jnp.stack(parts, axis=-1).reshape(-1)
        R = R_ref[w]
        delta = 2.0 * t * R * codes - R
        delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
        acc = acc + delta * keep_ref[w]
    out_ref[...] = acc


def dequant_acc_pallas(packed, R, keep, bits: int, n: int, *,
                       interpret: bool = True):
    """packed: [W, n*bits/8] uint8; R, keep: [W] f32 -> f32 [n] (summed)."""
    assert bits in (2, 4, 8), bits
    W, nbytes = packed.shape
    in_block = BLOCK * bits // 8
    assert nbytes % in_block == 0, (nbytes, in_block)
    grid = (nbytes // in_block,)
    return pl.pallas_call(
        functools.partial(_dequant_acc_kernel, bits, W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((W, in_block), lambda i: (0, i)),
            pl.BlockSpec((W,), lambda i: (0,)),
            pl.BlockSpec((W,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(packed, R, keep)
