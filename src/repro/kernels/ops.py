"""Jit'd public wrappers around the Pallas wire kernels.

Handles padding to the kernel block size, flat<->leaf reshaping, and backend
selection: interpret=True on CPU (the validation container), compiled Pallas
on TPU.  Covers the full adaptive-LAQ width grid: b in {2, 4, 8} packs
4 / 2 / 1 codes per byte.

The production entry point is the ``fused`` wire backend in
``repro.core.wire``, which routes the per-worker hot loop through
:func:`absmax` (pass 1) and :func:`quantize_pack_fused` (pass 2) on TPU and
through an op-for-op jnp lowering of the same two-pass algorithm on CPU,
where interpret-mode Pallas would serialize the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant_pack import (BLOCK, absmax_pallas, dequant_acc_pallas,
                         quantize_codes_adaptive_pallas, quantize_codes_pallas,
                         quantize_pack_adaptive_pallas, quantize_pack_pallas,
                         quantize_pack_payload_pallas,
                         sparse_quant_pack_pallas)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to_block(flat):
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def _pad_pair(grad, qhat):
    g = grad.astype(jnp.float32).reshape(-1)
    qh = qhat.astype(jnp.float32).reshape(-1)
    g, n = _pad_to_block(g)
    qh, _ = _pad_to_block(qh)
    return g, qh, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def absmax(grad, qhat, *, interpret: bool | None = None):
    """Pass 1: R = ||grad - qhat||_inf without materializing the diff.

    grad/qhat f32 (any shape, flattened); returns a f32 scalar.  Zero
    padding is harmless (pad diff is 0, abs-max >= 0).
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, _ = _pad_pair(grad, qhat)
    partial_max = absmax_pallas(g, qh, interpret=interpret)
    return jnp.max(partial_max)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack_fused(grad, qhat, R, bits: int, *,
                        interpret: bool | None = None):
    """Pass 2: fused quantize+pack with moment side-outputs.

    grad/qhat f32 [n], R scalar.  Returns ``(packed uint8
    [ceil(n/blk)*blk*bits/8], delta f32 [n], q_new f32 [n], err_sq,
    innovation_sq)`` where the scalar moments are the block-partial sums of
    ||grad - q_new||^2 and ||delta||^2 over the n real elements.
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, n = _pad_pair(grad, qhat)
    packed, delta, q_new, err_p, inn_p = quantize_pack_pallas(
        g, qh, R.astype(jnp.float32).reshape(1), bits, n, interpret=interpret)
    return packed, delta[:n], q_new[:n], jnp.sum(err_p), jnp.sum(inn_p)


@functools.partial(jax.jit, static_argnames=("grid", "interpret"))
def quantize_pack_adaptive(grad, qhat, R, onehot, grid: tuple, *,
                           interpret: bool | None = None):
    """Adaptive pass 2: the width-grid-unrolled fused quantize+pack sweep.

    grad/qhat f32 (any shape, flattened), R scalar, ``onehot`` f32 [len(grid)]
    indicator of the selected width (adaptive.select_bits), ``grid`` the
    static ascending width tuple.  Returns ``(packed uint8
    [ceil(n/blk)*blk*max(grid)/8], delta f32 [n], q_new f32 [n], err_sq,
    innovation_sq)`` — the payload is provisioned at max(grid) bits (the
    sharded wire's static-shape convention); a pinned selection reproduces
    :func:`quantize_pack_fused` at that width bit-for-bit (each switch arm
    IS the static-width kernel body).
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, n = _pad_pair(grad, qhat)
    sel = jnp.argmax(onehot).astype(jnp.int32).reshape(1)
    packed, delta, q_new, err_p, inn_p = quantize_pack_adaptive_pallas(
        g, qh, R.astype(jnp.float32).reshape(1), sel, grid, n,
        interpret=interpret)
    return packed, delta[:n], q_new[:n], jnp.sum(err_p), jnp.sum(inn_p)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_codes_fused(grad, qhat, R, bits: int, *,
                         interpret: bool | None = None):
    """Pass 2 for the streamed sharded wire: codes + delta in one sweep,
    codes left UNPACKED (the sharded wire packs along the leaf's last dim
    itself — core/wire.py pack_codes_along_axis).

    grad/qhat f32 (any shape, flattened), R scalar.  Returns ``(codes uint8
    [n], delta f32 [n])`` sliced to the real length (callers reshape back
    to the leaf shape).
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, n = _pad_pair(grad, qhat)
    codes, delta = quantize_codes_pallas(
        g, qh, R.astype(jnp.float32).reshape(1), bits, interpret=interpret)
    return codes[:n], delta[:n]


@functools.partial(jax.jit, static_argnames=("grid", "interpret"))
def quantize_codes_adaptive(grad, qhat, R, onehot, grid: tuple, *,
                            interpret: bool | None = None):
    """Traced-width variant of :func:`quantize_codes_fused` (``onehot``
    selects from the static ``grid`` via one ``lax.switch`` arm per width).
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, n = _pad_pair(grad, qhat)
    sel = jnp.argmax(onehot).astype(jnp.int32).reshape(1)
    codes, delta = quantize_codes_adaptive_pallas(
        g, qh, R.astype(jnp.float32).reshape(1), sel, grid,
        interpret=interpret)
    return codes[:n], delta[:n]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack(grad, qhat, R, bits: int, *, interpret: bool | None = None):
    """Flat leaf quantize+pack. grad/qhat f32 [n], R scalar.

    Returns (packed uint8 [ceil(n/blk)*blk*bits/8], delta f32 [n]).
    The payload-only kernel: no q_new/moment outputs, so payload-only
    callers don't pay their VMEM writes (use quantize_pack_fused when the
    criterion moments are wanted too).
    """
    if interpret is None:
        interpret = _on_cpu()
    g, qh, n = _pad_pair(grad, qhat)
    packed, delta = quantize_pack_payload_pallas(
        g, qh, R.astype(jnp.float32).reshape(1), bits, interpret=interpret)
    return packed, delta[:n]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def sparse_quantize_pack(vals, lo, hi, bits: int, *,
                         interpret: bool | None = None):
    """Sparse-pipeline quantize+pack on the gathered survivor values.

    vals f32 [k] (any k, padded to the kernel block here), lo/hi the
    sign-magnitude grid-endpoint sidecar scalars.  Returns ``(packed uint8
    [ceil(k/blk)*blk*bits/8], codes uint8 [k], deq f32 [k])`` — codes/deq
    sliced to the k real survivors; the packed buffer keeps the block pad
    (the canonical payload is re-packed from the sliced codes by
    core/wire.py's shared path).
    """
    if interpret is None:
        interpret = _on_cpu()
    v, k = _pad_to_block(vals.astype(jnp.float32).reshape(-1))
    packed, codes, deq = sparse_quant_pack_pallas(
        v, lo.astype(jnp.float32).reshape(1),
        hi.astype(jnp.float32).reshape(1), bits, interpret=interpret)
    return packed, codes[:k], deq[:k]


@functools.partial(jax.jit, static_argnames=("bits", "n", "interpret"))
def dequant_acc(packed, R, keep, bits: int, n: int, acc=None, *,
                interpret: bool | None = None):
    """Server-side unpack+dequant+accumulate over the worker dim.

    ``acc`` (optional f32 [n], e.g. the server aggregate) is folded into the
    same pass: out = acc + sum_w keep_w * delta_w.
    """
    if interpret is None:
        interpret = _on_cpu()
    n_padded = packed.shape[1] * 8 // bits
    acc_padded = None
    if acc is not None:
        acc_padded, _ = _pad_to_block(acc.astype(jnp.float32).reshape(-1))
        assert acc_padded.shape[0] == n_padded, (acc.shape, n_padded)
    out = dequant_acc_pallas(packed, R.astype(jnp.float32),
                             keep.astype(jnp.float32), bits, n_padded,
                             acc_padded, interpret=interpret)
    return out[:n]
