"""Jit'd public wrappers around the Pallas wire kernels.

Handles padding to the kernel block size, flat<->leaf reshaping, and backend
selection: interpret=True on CPU (the validation container), compiled Pallas
on TPU.  Covers the full adaptive-LAQ width grid: b in {2, 4, 8} packs
4 / 2 / 1 codes per byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant_pack import BLOCK, dequant_acc_pallas, quantize_pack_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to_block(flat):
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack(grad, qhat, R, bits: int, *, interpret: bool | None = None):
    """Flat leaf quantize+pack. grad/qhat f32 [n], R scalar.

    Returns (packed uint8 [ceil(n/blk)*blk*bits/8], delta f32 [n]).
    """
    if interpret is None:
        interpret = _on_cpu()
    diff = grad.astype(jnp.float32) - qhat.astype(jnp.float32)
    diff, n = _pad_to_block(diff.reshape(-1))
    packed, delta = quantize_pack_pallas(diff, R.reshape(1), bits,
                                         interpret=interpret)
    return packed, delta[:n]


@functools.partial(jax.jit, static_argnames=("bits", "n", "interpret"))
def dequant_acc(packed, R, keep, bits: int, n: int, *,
                interpret: bool | None = None):
    """Server-side unpack+dequant+accumulate over the worker dim."""
    if interpret is None:
        interpret = _on_cpu()
    n_padded = packed.shape[1] * 8 // bits
    out = dequant_acc_pallas(packed, R.astype(jnp.float32),
                             keep.astype(jnp.float32), bits, n_padded,
                             interpret=interpret)
    return out[:n]
