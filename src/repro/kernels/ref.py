"""Pure-jnp oracles for the LAQ wire kernels (the source of truth in tests).

Semantics mirror core/quantize.py exactly, specialized to flat float32
vectors with a precomputed radius (the kernels operate post-flattening, one
leaf at a time; the radius reduction itself is a cheap jnp.max upstream).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_pack_ref(diff: jnp.ndarray, R: jnp.ndarray, bits: int):
    """diff = grad - qhat, flat f32 [n] (n even for bits=4).

    Returns (packed uint8 [n*bits/8], q_new_delta f32 [n]) where
    q_new_delta = dequantize(codes) (the innovation actually applied).
    """
    assert bits in (2, 4, 8)
    t = 1.0 / (2.0 ** bits - 1.0)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((diff + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    if bits == 2:
        packed = (q[0::4] | (q[1::4] << 2) | (q[2::4] << 4)
                  | (q[3::4] << 6)).astype(jnp.uint8)
    elif bits == 4:
        packed = (q[0::2] | (q[1::2] << 4)).astype(jnp.uint8)
    else:
        packed = q
    return packed, delta


def dequant_acc_ref(packed: jnp.ndarray, R: jnp.ndarray, keep: jnp.ndarray,
                    bits: int, n: int):
    """packed [W, n*bits/8] uint8, R [W], keep [W] -> sum_w delta_w, f32 [n]."""
    assert bits in (2, 4, 8)
    t = 1.0 / (2.0 ** bits - 1.0)
    if bits < 8:
        mask = (1 << bits) - 1
        parts = [((packed >> (bits * j)) & mask).astype(jnp.float32)
                 for j in range(8 // bits)]
        codes = jnp.stack(parts, axis=-1).reshape(packed.shape[0], -1)[:, :n]
    else:
        codes = packed.astype(jnp.float32)[:, :n]
    Rw = R[:, None]
    delta = 2.0 * t * Rw * codes - Rw
    delta = jnp.where(Rw > 0, delta, 0.0) * keep[:, None]
    return jnp.sum(delta, axis=0)
