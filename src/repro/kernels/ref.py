"""Pure-jnp oracles for the LAQ wire kernels (the source of truth in tests).

Semantics mirror core/quantize.py exactly, specialized to flat float32
vectors (the kernels operate post-flattening, one leaf at a time).  Covers
both kernel passes: the absmax radius reduction and the fused
quantize+pack+moments sweep, plus the accumulating receive side.
"""
from __future__ import annotations

import jax.numpy as jnp


def absmax_ref(grad: jnp.ndarray, qhat: jnp.ndarray) -> jnp.ndarray:
    """R = ||grad - qhat||_inf, f32 scalar (pass-1 oracle)."""
    d = grad.astype(jnp.float32) - qhat.astype(jnp.float32)
    return jnp.max(jnp.abs(d)).astype(jnp.float32)


def quantize_pack_ref(diff: jnp.ndarray, R: jnp.ndarray, bits: int):
    """diff = grad - qhat, flat f32 [n] (n a multiple of 8/bits).

    Returns (packed uint8 [n*bits/8], q_new_delta f32 [n]) where
    q_new_delta = dequantize(codes) (the innovation actually applied).
    """
    assert bits in (1, 2, 4, 8)
    t = 1.0 / (2.0 ** bits - 1.0)
    levels = 2 ** bits - 1
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((diff + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    if bits == 8:
        packed = q
    else:
        cpb = 8 // bits
        packed = q[0::cpb]
        for j in range(1, cpb):
            packed = packed | (q[j::cpb] << (bits * j))
        packed = packed.astype(jnp.uint8)
    return packed, delta


def quantize_pack_fused_ref(grad: jnp.ndarray, qhat: jnp.ndarray,
                            R: jnp.ndarray, bits: int):
    """Oracle for the fused pass-2 kernel on *unpadded* inputs.

    Returns ``(packed, delta, q_new, err_sq, innovation_sq)`` with the same
    association order as the kernel: q_new = qhat + delta, err = grad - q_new.
    """
    g = grad.astype(jnp.float32)
    qh = qhat.astype(jnp.float32)
    n = g.shape[0]
    pad = (-n) % (8 // bits)          # packing needs whole bytes; the pad
    d = g - qh                        # codes are sliced off by the caller
    if pad:
        d = jnp.concatenate([d, jnp.zeros((pad,), jnp.float32)])
    packed, delta = quantize_pack_ref(d, R, bits)
    delta = delta[:n]
    q_new = qh + delta
    err = g - q_new
    return packed, delta, q_new, jnp.sum(err * err), jnp.sum(delta * delta)


def quantize_pack_adaptive_ref(grad: jnp.ndarray, qhat: jnp.ndarray,
                               R: jnp.ndarray, grid: tuple, sel: int):
    """Oracle for the adaptive (width-switched) fused pass-2 kernel on
    *unpadded* inputs: the static-width pipeline at ``bits = grid[sel]``,
    with the payload packed at the provision width ``max(grid)`` (codes
    < 2^b always fit the wider lanes; the sharded wire's static-shape
    provisioning convention).

    Returns ``(packed, delta, q_new, err_sq, innovation_sq)`` exactly like
    :func:`quantize_pack_fused_ref`.
    """
    bits = grid[sel]
    provision = max(grid)
    g = grad.astype(jnp.float32)
    qh = qhat.astype(jnp.float32)
    n = g.shape[0]
    t = 1.0 / (2.0 ** bits - 1.0)
    levels = 2 ** bits - 1
    d = g - qh
    pad = (-n) % (8 // provision)     # provision-width packing needs whole
    if pad:                           # bytes; pad diff is 0 like the kernel's
        d = jnp.concatenate([d, jnp.zeros((pad,), jnp.float32)])
    denom = jnp.where(R > 0, 2.0 * t * R, 1.0)
    q = jnp.floor((d + R) / denom + 0.5)
    q = jnp.clip(q, 0, levels)
    q = jnp.where(R > 0, q, (levels + 1) // 2 * jnp.ones_like(q)).astype(jnp.uint8)
    delta = 2.0 * t * R * q.astype(jnp.float32) - R
    delta = jnp.where(R > 0, delta, jnp.zeros_like(delta))
    if provision == 8:
        packed = q
    else:
        cpb = 8 // provision
        packed = q[0::cpb]
        for j in range(1, cpb):
            packed = packed | (q[j::cpb] << (provision * j))
        packed = packed.astype(jnp.uint8)
    delta = delta[:n]
    q_new = qh + delta
    err = g - q_new
    return packed, delta, q_new, jnp.sum(err * err), jnp.sum(delta * delta)


def dequant_acc_ref(packed: jnp.ndarray, R: jnp.ndarray, keep: jnp.ndarray,
                    bits: int, n: int, acc: jnp.ndarray = None):
    """packed [W, n*bits/8] uint8, R [W], keep [W] -> sum_w delta_w, f32 [n].

    ``acc`` (optional f32 [n]) is the server aggregate folded into the sum.
    """
    assert bits in (1, 2, 4, 8)
    t = 1.0 / (2.0 ** bits - 1.0)
    if bits < 8:
        mask = (1 << bits) - 1
        parts = [((packed >> (bits * j)) & mask).astype(jnp.float32)
                 for j in range(8 // bits)]
        codes = jnp.stack(parts, axis=-1).reshape(packed.shape[0], -1)[:, :n]
    else:
        codes = packed.astype(jnp.float32)[:, :n]
    Rw = R[:, None]
    delta = 2.0 * t * Rw * codes - Rw
    delta = jnp.where(Rw > 0, delta, 0.0) * keep[:, None]
    out = jnp.sum(delta, axis=0)
    return out if acc is None else acc.astype(jnp.float32) + out
