"""Serving steps: prefill + single-token decode with sharded caches.

``decode_32k`` / ``long_500k`` lower these (one new token against a KV cache
of ``seq_len``), not train_step.  The KV cache sequence dim is sharded over
the ``model`` axis (flash-decode); recurrent caches (mamba) shard heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import (cache_pspecs, decode_step, init_cache, init_params,
                          param_pspecs, prefill)
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens):
        return prefill(params, tokens, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg)
    return serve_step


def _dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def serve_specs(cfg: ModelConfig, mesh, batch: int, seq_len: int, *,
                decode_pos: int | None = None):
    """(params_specs, cache_specs, tokens_specs) as sharded SDS for lowering.

    For decode shapes the cache is sized/validated at ``seq_len`` (ring
    buffer of ``sliding_window`` when configured) with ``pos = decode_pos``.
    """
    dp = _dp_axes(mesh)
    data_size = 1
    for a in dp:
        data_size *= mesh.shape[a]
    model_size = mesh.shape["model"]

    params_abs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_pspecs(cfg, params_abs, model_size)
    params_s = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_abs, pspecs)

    cache_abs = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    cspecs = cache_pspecs(cfg, cache_abs, data_size, model_size,
                          data_axis=dp if len(dp) > 1 else dp[0])
    cache_s = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        cache_abs, cspecs)

    tok_spec = P(dp if batch % data_size == 0 else None, None)
    tokens_s = jax.ShapeDtypeStruct((batch, 1), jnp.int32,
                                    sharding=NamedSharding(mesh, tok_spec))
    return params_s, cache_s, tokens_s
