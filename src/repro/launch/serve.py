"""Serving steps: prefill + single-token decode with sharded caches.

``decode_32k`` / ``long_500k`` lower these (one new token against a KV cache
of ``seq_len``), not train_step.  The KV cache sequence dim is sharded over
the ``model`` axis (flash-decode); recurrent caches (mamba) shard heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import (cache_pspecs, decode_step, init_cache, init_params,
                          param_pspecs, prefill)
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens):
        return prefill(params, tokens, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg)
    return serve_step


def make_greedy_decode_step(cfg: ModelConfig):
    """One-token greedy decode with the argmax folded into the jitted body:
    (params, cache, tokens[B,1]) -> (next_tokens[B,1] int32, new cache).

    Keeping token selection on-device means the decode loop never pulls
    logits ([B,1,V] f32) back to the host — only the [B,1] int32 token ids
    cross, and only when the caller asks for them.
    """
    def greedy_step(params, cache, tokens):
        logits, cache = decode_step(params, cache, tokens, cfg)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
        return nxt, cache
    return greedy_step


def make_greedy_prefill_step(cfg: ModelConfig, max_len: int):
    """Prefill returning (first_greedy_token[B,1] int32, cache) — the
    argmax over the last-position logits folded into the jit, mirroring
    :func:`make_greedy_decode_step`."""
    def greedy_prefill(params, tokens):
        logits, cache = prefill(params, tokens, cfg, max_len)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
        return nxt, cache
    return greedy_prefill


def jit_serve(cfg: ModelConfig, max_len: int):
    """(jitted greedy prefill, jitted greedy decode) for the serve loop.

    The decode jit **donates the cache argument** (arg 1): the KV cache is
    by far the largest serve-time buffer and is dead the moment the step
    returns the updated one, so without donation every decoded token pays
    a full cache copy.  Callers must treat the passed-in cache as consumed
    (rebind to the returned one) — and must warm the jit with a throwaway
    cache first, since the warmup call eats its input too.
    """
    prefill_fn = jax.jit(make_greedy_prefill_step(cfg, max_len))
    decode_fn = jax.jit(make_greedy_decode_step(cfg), donate_argnums=(1,))
    return prefill_fn, decode_fn


def _dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def serve_specs(cfg: ModelConfig, mesh, batch: int, seq_len: int, *,
                decode_pos: int | None = None):
    """(params_specs, cache_specs, tokens_specs) as sharded SDS for lowering.

    For decode shapes the cache is sized/validated at ``seq_len`` (ring
    buffer of ``sliding_window`` when configured) with ``pos = decode_pos``.
    """
    dp = _dp_axes(mesh)
    data_size = 1
    for a in dp:
        data_size *= mesh.shape[a]
    model_size = mesh.shape["model"]

    params_abs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_pspecs(cfg, params_abs, model_size)
    params_s = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_abs, pspecs)

    cache_abs = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    cspecs = cache_pspecs(cfg, cache_abs, data_size, model_size,
                          data_axis=dp if len(dp) > 1 else dp[0])
    cache_s = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        cache_abs, cspecs)

    tok_spec = P(dp if batch % data_size == 0 else None, None)
    tokens_s = jax.ShapeDtypeStruct((batch, 1), jnp.int32,
                                    sharding=NamedSharding(mesh, tok_spec))
    return params_s, cache_s, tokens_s
