"""Distributed LAQ training step.

The gradient computation + LAQ aggregation run inside a **partial-auto
shard_map**: manual over the worker axes (``data``, and ``pod`` on multi-pod
meshes), auto over ``model``.  Inside the manual region each worker sees its
own batch shard and computes a *local* gradient (no implicit data-axis psum —
that is exactly what GSPMD would insert for replicated params, and what LAQ
must intercept).  The LAQ state machine quantizes the innovation, applies the
skip criterion, and the aggregation collective is explicit:

* ``wire="float"``  — psum of the (dequantized, skip-masked) innovations.
  Numerically exact LAQ; bits accounted analytically (paper's accounting).
* ``wire="packed"`` — the TPU-native wire format: per-leaf b-bit codes packed
  into uint8 payloads and exchanged with ``all_gather`` over the worker axes
  together with the per-worker radius R and skip mask; every device
  dequantizes and sums (the SPMD replica of the paper's server).  The
  collective payload is physically b/32 of the float gradient — visible in
  the dry-run HLO and the roofline collective term.  Pays off at pod
  granularity (W=2) where the exchange crosses the slow DCN link.

Packed wire format (per worker, per round):

* **fixed-bit** (``bit_schedule`` None/constant, width b in {2, 4, 8}) —
  per leaf, codes packed little-end-first at 8/b codes per byte when the
  leaf's last dim divides 8/b (odd last dims ship raw uint8 codes), plus two
  sidecars exchanged once per round: the radius ``R`` (f32 per leaf for
  ``per_leaf_radius``, else one global f32) and the skip-mask bit.
* **adaptive** (``bit_schedule`` radius/budget, core/adaptive.py) — each
  worker additionally announces its selected width ``b_m^k`` as a third
  sidecar, and every receiver decodes with the sender's tau(b_m^k).  The
  payload buffer is *provisioned* at the static width max(grid) — SPMD
  collectives need static shapes, so the adaptivity shows up in the exact
  wire-bit accounting (``upload_bits`` with variable b + the width sidecar)
  rather than in the buffer shape; a grid capped below 8 shrinks the
  physical buffer correspondingly.  Decode taus come from a grid-table
  lookup, never ``1/(2^b - 1)`` float arithmetic, so packed and float wires
  stay bit-identical.
* **0.4.x jax degradation** — the 0.4.x partitioner only lowers ``psum``
  inside partial-auto shard_map (compat.SUPPORTS_PARTIAL_AUTO_COLLECTIVES),
  so the exchange falls back to each worker decoding its *own* payload
  through the identical pack->unpack->dequant math and psum-ing the f32
  delta: bit-identical results, analytic bit accounting, no physical byte
  saving on that jax.

The skip criterion is pluggable (``StrategyConfig.lazy_rule``): the paper's
eq. 7a, or the variance-aware LASG rules (core/lazy_rules.py) whose
per-worker estimator state (``CommState.lazy``: variance / smoothness EMAs,
plus the stale-iterate snapshot for ``lasg_wk2`` / ``lasg_ps``) and the
scale-free adaptive threshold anchor (``CommState.R_anchor``) ride through
the sharded step like ``qhat`` — one slice per worker shard, reference wire
path.  The ``lasg_wk2`` rule pays a second backprop per step: the *current*
batch re-evaluated at this worker's stale iterate (same microbatching), so
its skip decision is noise-free.

Upload defense (``StrategyConfig.defense``, core/defense.py) runs inside
the sharded step: validation finite-checks each worker's innovation and
quantization error against a per-worker accepted-norm EMA, and a rejected
upload is masked off the wire exactly like a lazy skip (bits still paid —
the ``committed`` mask; docs/robustness.md).  Fault *injection*, robust
aggregation (``aggregator != "sum"``) and norm clipping on the packed wire
are simulated-engine-only and asserted off here.

Three stochastic levers from the simulated engine also apply here — the
round stages themselves are SHARED with ``core/engine.py`` (this module no
longer carries its own copy of the SVRG / WK2 round math):

* ``StrategyConfig.eta_schedule`` — the per-round stepsize ``alpha_k``
  (computed from the replicated ``comm.step``) feeds both the optimizer
  step and the criterion's ``1/(alpha^2 M^2)`` term;
* ``StrategyConfig.grad_mode="svrg"`` — **streaming-anchor** variance
  reduction via :func:`repro.core.engine.apply_svrg_streaming`: every
  ``svrg_period`` steps the anchor snaps to the current iterate and ``mu``
  to the current *batch* gradient (the launch path streams data, so the
  simulated engine's exact full-local-data anchor is approximated by a
  one-batch anchor; the anchor noise is frozen for the period rather than
  eliminated — a documented degradation).  Corrected gradients feed the
  lazy rule and the quantizer exactly as in the simulated engine; the
  anchor state (``CommState.svrg``) rides per worker shard like ``qhat``;
* ``StrategyConfig.participation`` — partial participation
  (core/engine.py): ``"bernoulli"`` / ``"fixed_k"`` client sampling draws
  the round's cohort from :func:`repro.core.engine.participation_mask`
  (deterministic in ``(participation_seed, step)``, so every shard and the
  simulated engine agree on who is reachable); each shard indexes its slot
  of the replicated [W] mask by a *worker-index input* sharded over the
  worker axes — NOT ``jax.lax.axis_index``, which lowers to a PartitionId
  instruction the 0.4.x partial-auto partitioner rejects (see
  ``repro/compat.py``).  Unreachable workers are masked exactly like lazy
  skips inside ``worker_update`` (no upload, no wire bits, clocks grow).
  ``"delay"`` (bounded-staleness async) is simulated-engine-only: it needs
  a replicated params-history ring, which at model scale would be
  ``max_delay`` extra copies of the parameters — asserted off here, see
  ``docs/engine.md``.

Tensor parallelism (``model`` axis) stays under GSPMD: inside the manual
region, model-sharded arrays keep their global shapes and einsum/norm
reductions over them lower to the usual collectives.

The packed wire byte layout this module exchanges is specified normatively
in ``docs/wire-format.md``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.adaptive import eta_at, tau_of_selection, tau_of_width
from repro.core.compressors import ErrorState, compressor_keys
from repro.core.defense import DefenseState
from repro.core.engine import (accumulate_loss_grads, apply_svrg_streaming,
                               participation_mask, stale_side_grads)
from repro.core.quantize import tree_sq_norm
from repro.core.strategy import (CommState, StrategyConfig, SvrgState,
                                 worker_update)
from repro.core.wire import (get_backend, pack_codes_along_axis,
                             unpack_codes_along_axis)
from repro.core.criterion import push_history
from repro.models import lm_loss, param_pspecs
from repro.models.config import ModelConfig
from repro.optim import Optimizer

from .mesh import n_workers_of


class TrainState(NamedTuple):
    params: object
    opt_state: object
    comm: CommState
    step: jax.Array


class StepMetrics(NamedTuple):
    loss: jax.Array
    uploads: jax.Array
    bits: jax.Array
    grad_sq: jax.Array


def _squeeze0(tree):
    return jax.tree.map(lambda x: jnp.squeeze(x, 0), tree)


def _unsqueeze0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def _axis_size_static(worker_axes) -> int:
    mesh = compat.get_abstract_mesh()
    axes = (worker_axes,) if isinstance(worker_axes, str) else worker_axes
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


def resolve_wire_backend(strategy: StrategyConfig) -> StrategyConfig:
    """The sharded step's wire-backend policy (the jax >= 0.5 migration).

    On jax >= 0.5 the partial-auto partitioner lowers Pallas calls and the
    flat per-leaf reshapes the fused pipeline needs
    (compat.SUPPORTS_PALLAS_PARTIAL_AUTO), so the requested backend is
    honored as-is.  On 0.4.x those lowerings hard-abort inside the
    partially-manual region, so a non-reference request downgrades to the
    bit-identical ``reference`` pipeline — with a one-time log warning, not
    silently (the historical silent ``_replace`` hid the downgrade from
    users benchmarking the fused wire).  The resolved name is exposed on
    the returned step fn as ``step.wire_backend``.
    """
    if get_backend(strategy.wire_backend).name == "reference":
        return strategy
    if compat.SUPPORTS_PALLAS_PARTIAL_AUTO:
        return strategy
    compat.warn_once(
        "sharded-wire-backend-downgrade",
        f"jax {jax.__version__} < 0.5: the partial-auto partitioner cannot "
        "lower the fused wire backend's Pallas kernels (nor the flat "
        "per-leaf reshapes) under shard_map; the sharded step downgrades "
        f"wire_backend={get_backend(strategy.wire_backend).name!r} to "
        "'reference'. Wire content is bit-identical across backends "
        "(core/wire.py contract); upgrade jax >= 0.5 to run the fused "
        "pipeline here.")
    return strategy._replace(wire_backend="reference")


def exchange_mode(n_workers: int) -> str:
    """Which collective carries the packed payload across workers — a pure
    function of worker count and jax capability, factored out so the
    version-gated selection is testable without building a mesh
    (tests/test_compat.py pins the flip):

    * ``"gather"`` — all_gather payload + sidecars; every device decodes
      and masked-sums all W payloads (the SPMD server replica).
    * ``"permute"`` — W == 2 (pod pairs): one collective-permute payload
      swap instead of a gather.
    * ``"local_decode_psum"`` — deprecated 0.4.x degradation (the
      partitioner lowers only psum in partial-auto regions): each worker
      decodes its OWN payload and the transport is a float psum.
      Bit-identical, analytically accounted, but no physical byte saving;
      dead on jax >= 0.5, scheduled for deletion with the 0.4.37 CI pin.
    """
    if not compat.SUPPORTS_PARTIAL_AUTO_COLLECTIVES:
        return "local_decode_psum"
    return "permute" if n_workers == 2 else "gather"


def _packed_aggregate(grads, qhat, skip_mask, strategy: StrategyConfig,
                      worker_axes, pspecs=None, width=None):
    """The packed-uint8 wire, **streamed one leaf at a time**: per leaf,
    innovation -> quantize -> pack -> exchange -> dequantize -> masked sum
    (plus that leaf's local ``q_new`` reconstruction) before the next leaf
    is touched.  Returns (sum_of_innovations, q_new_tree).

    Memory frugality at LM scale: the program never materializes a
    whole-model codes / diff / delta pytree — one leaf's quantize/pack
    intermediates are live at a time, so the transient footprint is
    O(max-leaf) instead of O(model).  The only whole-tree pre-pass is the
    radius: a *scalar* absmax per leaf (global-radius mode maxes the
    scalars with exactly ``tree_inf_norm``'s reduction), so the per-leaf
    code math stays bit-identical to ``quantize_innovation`` /
    ``dequantize_innovation`` (the packed-vs-float parity pinned by
    tests/test_system.py).

    ``pspecs`` (a pytree of PartitionSpec matching ``grads``) pins the
    payload's model-axis sharding through the exchange: without it GSPMD
    replicates the payload over ``model`` *before* the worker-axis
    all_gather, multiplying the exchanged bytes by the model-axis size.

    ``width`` (the per-shard selected bit-width from ``worker_update``)
    switches on the adaptive wire: codes are produced at the selected width,
    the buffer is provisioned at max(grid), and the width rides along as a
    sidecar so receivers decode with the sender's tau (see module docstring).
    """
    from repro.models.layers import maybe_constrain
    per_leaf = strategy.per_leaf_radius
    adaptive = width is not None
    if adaptive:
        grid = strategy.bit_schedule.grid
        onehot = (jnp.asarray(grid, jnp.float32) == width).astype(jnp.float32)
        provision = max(grid)
    else:
        bits = strategy.effective_bits
        provision = bits
    keep = jnp.logical_not(skip_mask).astype(jnp.float32)
    backend = get_backend(strategy.wire_backend)
    n_workers = _axis_size_static(worker_axes)
    mode = exchange_mode(n_workers)
    use_gather = mode == "gather"
    use_permute = mode == "permute"
    # per-round sidecars exchanged ONCE, outside the per-leaf loop (XLA does
    # not CSE collectives; a per-leaf exchange would issue one tiny
    # collective per parameter tensor)
    _perm2 = [(0, 1), (1, 0)]
    t_self = tau_of_selection(grid, onehot) if adaptive else None
    if use_gather:
        keep_w = jax.lax.all_gather(keep, worker_axes)              # [W]
        if adaptive:
            width_w = jax.lax.all_gather(width, worker_axes)        # [W] sidecar
            tau_w = tau_of_width(grid, width_w)                     # [W]
    elif use_permute:
        peer_keep = jax.lax.ppermute(keep, worker_axes, _perm2)
        if adaptive:
            t_peer = jax.lax.ppermute(t_self, worker_axes, _perm2)

    # the axis-packed payload codec lives in core/wire.py (one wire format
    # shared with the backend interface): pack 8/b codes per byte ALONG THE
    # LAST DIM (no flatten: a flatten of a model-sharded leaf forces GSPMD
    # to regather it, and at large meshes trips an XLA spmd_partitioner
    # assertion); indivisible last dims and provision 8 ship raw codes
    def leaf_payload(q):
        return pack_codes_along_axis(q, provision)

    def leaf_unpack(payload, orig):
        return unpack_codes_along_axis(payload, provision, orig)

    def gather_dequant_sum(q, R, orig, spec):
        pl = leaf_payload(q)
        if spec is not None:
            pl = maybe_constrain(pl, *spec)
        payload = jax.lax.all_gather(pl, worker_axes)               # [W, ...]
        if spec is not None:
            payload = maybe_constrain(payload, None, *spec)
        Rw = jax.lax.all_gather(R, worker_axes)                     # [W]
        W = Rw.shape[0]
        codes = jax.vmap(lambda p_: leaf_unpack(p_, orig))(payload)
        if adaptive:
            t = tau_w.reshape((W,) + (1,) * orig.ndim)
        else:
            t = 1.0 / (2.0 ** provision - 1.0)
        Rb = Rw.reshape((W,) + (1,) * orig.ndim)
        kb = keep_w.reshape((W,) + (1,) * orig.ndim)
        delta = (2.0 * t * Rb * codes.astype(jnp.float32) - Rb)
        delta = jnp.where(Rb > 0, delta, 0.0) * kb
        return jnp.sum(delta, axis=0)

    def local_decode_psum(q, R, orig, spec):
        # DEPRECATED 0.4.x degradation (dead on jax >= 0.5 — see
        # exchange_mode; delete with the 0.4.37 CI pin): the partial-auto
        # partitioner only lowers psum, so every worker decodes its OWN
        # payload through the full pack->unpack->dequant wire math and the
        # transport is a float psum.  unpack(pack(codes)) == codes, so this
        # is bit-identical to the real payload exchange — only the bytes on
        # the link differ (accounting stays analytic either way).
        codes = leaf_unpack(leaf_payload(q), orig).astype(jnp.float32)
        t = t_self if adaptive else 1.0 / (2.0 ** provision - 1.0)
        d = 2.0 * t * R * codes - R
        d = jnp.where(R > 0, d, 0.0) * keep
        return jax.lax.psum(d, worker_axes)

    def permute_dequant_sum(q, R, orig, spec):
        # two-worker wire (pods): a single collective-permute payload
        # exchange — p*b/8 bytes on the link, nothing for GSPMD to re-shard
        pl = leaf_payload(q)
        if spec is not None:
            pl = maybe_constrain(pl, *spec)
        peer_pl = jax.lax.ppermute(pl, worker_axes, _perm2)
        peer_R = jax.lax.ppermute(R, worker_axes, _perm2)
        if adaptive:
            tv_self, tv_peer = t_self, t_peer
        else:
            tv_self = tv_peer = 1.0 / (2.0 ** provision - 1.0)

        def dq(codes_pl, Rv, tv):
            codes = leaf_unpack(codes_pl, orig).astype(jnp.float32)
            d = 2.0 * tv * Rv * codes - Rv
            return jnp.where(Rv > 0, d, 0.0)

        return (dq(pl, R, tv_self) * keep
                + dq(peer_pl, peer_R, tv_peer) * peer_keep)

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    qh_leaves = jax.tree_util.tree_leaves(qhat)
    s_leaves = (jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, tuple))
                if pspecs is not None else [None] * len(g_leaves))
    leaf_fn = {"gather": gather_dequant_sum,
               "permute": permute_dequant_sum,       # two-worker (pod) wire
               "local_decode_psum": local_decode_psum}[mode]

    # radius pre-pass: one scalar per leaf — the only whole-tree quantity.
    # The backend's pass-1 absmax primitive mirrors innovation() /
    # tree_inf_norm exactly (reference expressions on CPU; the fused
    # backend's blockwise Pallas reduction off-CPU), and for the global
    # radius a max over the stacked leaf scalars.
    absmax = [backend.leaf_absmax(g, qh)
              for g, qh in zip(g_leaves, qh_leaves)]
    if per_leaf:
        r_leaves = absmax
    else:
        R_glob = jnp.max(jnp.stack(absmax))
        r_leaves = [R_glob] * len(g_leaves)

    t_sel = tau_of_selection(grid, onehot) if adaptive else None

    def stream_leaf(g, qh, R, spec):
        # the streamed hot path: this leaf's codes, payload and dequantized
        # delta are dead before the next leaf starts.  The send-side sweep
        # is the backend's pass-2 leaf primitive: reference expressions on
        # the reference backend (and the fused backend's CPU lowering), the
        # fused codes+delta Pallas kernel off-CPU.
        if adaptive:
            q, delta_local = backend.leaf_quantize_adaptive(
                g, qh, R, grid, onehot, t_sel)
        else:
            q, delta_local = backend.leaf_quantize(g, qh, R, bits)
        agg = leaf_fn(q, R, g, spec)
        q_new = qh.astype(jnp.float32) + delta_local
        return agg, q_new

    streamed = [stream_leaf(g, qh, r, s) for g, qh, r, s
                in zip(g_leaves, qh_leaves, r_leaves, s_leaves)]
    agg_delta = jax.tree_util.tree_unflatten(treedef, [a for a, _ in streamed])
    q_new = jax.tree_util.tree_unflatten(treedef, [qn for _, qn in streamed])
    return agg_delta, q_new


def make_train_step(cfg: ModelConfig, mesh, strategy: StrategyConfig,
                    optimizer: Optimizer, *, lr: float,
                    worker_axes=None, wire: str = "float",
                    hierarchical: bool = False, microbatch: int = 1):
    """Returns ``step(state, batch) -> (state, metrics)`` (to be jitted).

    ``microbatch > 1`` splits each worker's batch into that many sequential
    microbatches with f32 gradient accumulation — the standard production
    lever for the activation-memory term (saved activations shrink by the
    factor; LAQ semantics unchanged, it still sees the full-batch gradient).
    """
    from .mesh import worker_axes_of
    if worker_axes is None:
        worker_axes = worker_axes_of(mesh, hierarchical=hierarchical)
    W = n_workers_of(mesh, worker_axes)
    wa = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    assert wire in ("float", "packed")
    if strategy.compressed or strategy.error_feedback:
        # the packed wire re-quantizes the raw grads itself (dense per-leaf
        # codes); the sparse pipeline ships index+code payloads whose exact
        # byte layout the sharded exchange does not yet implement — the
        # compressor path rides the float wire with analytic bit accounting
        # (same documented degradation as the 0.4.x psum-only wire)
        assert wire == "float", \
            "compressor / error_feedback strategies require wire='float'"
        # global support selection flattens the whole gradient pytree; a
        # reshape of a model-sharded leaf inside partial-auto shard_map
        # forces a GSPMD regather that trips the 0.4.x spmd_partitioner
        # (the same physics that pins the reference backend below), and
        # the manual region cannot express the gather itself — so the
        # sparse pipeline covers data-parallel meshes only
        assert mesh.shape["model"] == 1, (
            "compressor / error_feedback strategies require a pure "
            "data-parallel mesh (model axis 1): global top-k/rand-k "
            "support selection flattens the gradient pytree, which the "
            "0.4.x partial-auto partitioner cannot reshard")
    assert strategy.participation in ("full", "bernoulli", "fixed_k"), (
        "delay/markov participation is simulated-engine-only: 'delay' would "
        "need a replicated params-history ring of max_delay+1 full parameter "
        "copies, and 'markov' carries a stateful per-worker on/off chain "
        "(see docs/engine.md)")
    assert strategy.max_delay == 0, "max_delay needs participation='delay'"
    assert not strategy.faults.active, (
        "fault injection is simulated-engine-only: the corruption / crash "
        "stages live in RoundEngine.round (core/engine.py), not the sharded "
        "step — the launch path is the *defended* deployment target "
        "(see docs/robustness.md)")
    assert strategy.aggregator == "sum", (
        "trimmed_mean/median aggregation is simulated-engine-only: the "
        "coordinate-wise sort needs every worker's dequantized delta on one "
        "device, which the 0.4.x partial-auto partitioner cannot express "
        "per-shard (see docs/robustness.md); the sharded defenses are "
        "validation + norm-gate + clip, which are per-worker-local")
    if wire == "packed":
        assert strategy.defense.clip_mult == 0.0, (
            "norm-clipping on the packed wire would need a per-worker f32 "
            "scale sidecar (codes are integers); clip rides the float wire, "
            "validate/gate work on both (a reject is one mask bit)")
    # jax >= 0.5: the requested wire backend runs as-is under the
    # partial-auto shard_map (Pallas lowers there now); 0.4.x downgrades to
    # the bit-identical reference pipeline with a one-time warning
    strategy = resolve_wire_backend(strategy)
    grad_pspecs = None
    if wire == "packed":
        assert strategy.quantized, "packed wire requires a quantized strategy"
        if strategy.adaptive:
            assert all(b in (2, 4, 8) for b in strategy.bit_schedule.grid), \
                "packed wire covers the {2,4,8} grid"
        else:
            assert strategy.effective_bits in (2, 4, 8), \
                "packed wire requires a 2-, 4- or 8-bit quantized strategy"
        from repro.models import init_params
        params_abs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        grad_pspecs = param_pspecs(cfg, params_abs, mesh.shape["model"])

    def sharded_step(params, opt_state, comm, batch, widx):
        qhat = _squeeze0(comm.qhat)
        eps_hat_sq = jnp.squeeze(comm.eps_hat_sq, 0)
        clock = jnp.squeeze(comm.clocks, 0)
        bits_spent = jnp.squeeze(comm.bits_spent, 0)
        lazy = _squeeze0(comm.lazy)        # LASG estimator state (this shard)
        R_anchor = jnp.squeeze(comm.R_anchor, 0)
        error = _squeeze0(comm.error)      # EF residual (this shard)
        defense = _squeeze0(comm.defense)  # gate EMA / reject ledger (shard)

        def loss_fn(p, b):
            return lm_loss(p, b, cfg) / W          # sum_m loss_m == global mean

        def loss_and_grads(at_params):
            """This worker's batch gradient at an arbitrary iterate (the
            current params; the WK2 stale iterate; the SVRG anchor) —
            microbatching identical for every evaluation point, via the
            engine-shared fold (core/engine.py accumulate_loss_grads, the
            same arithmetic AccumulatingSource runs in the simulated
            engine).  Probe mode (unrolled layers) unrolls the microbatch
            fold too so cost_analysis counts every pass."""
            if microbatch == 1:
                return jax.value_and_grad(loss_fn)(at_params, batch)
            mb = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            unroll = not (cfg.scan_layers and not compat.needs_loop_unrolling())
            return accumulate_loss_grads(loss_fn, at_params, mb,
                                         unroll=unroll)

        loss, grads = loss_and_grads(params)
        lr_k = eta_at(strategy.eta_schedule, lr, comm.step)

        svrg_new = comm.svrg
        corr = None
        if strategy.variance_reduced:
            # the shared streaming-anchor stage (core/engine.py; the
            # simulated engine uses the exact-anchor variant): the anchor
            # backprop runs every step — svrg's inherent 2x compute
            grads, corr, sv_new = apply_svrg_streaming(
                _squeeze0(comm.svrg), params, grads,
                lambda th: loss_and_grads(th)[1], comm.step, strategy)
            svrg_new = _unsqueeze0(sv_new)

        grads_stale = None
        if strategy.lazy and strategy.lazy_rule == "lasg_wk2":
            # the shared WK2 stage: the SAME batch at the stale iterate
            # (identical microbatching via loss_and_grads), svrg correction
            # applied to both sides so anchor and mu cancel
            grads_stale = stale_side_grads(lambda th: loss_and_grads(th)[1],
                                           lazy.theta_last, corr)

        avail = None
        if strategy.participation != "full":
            # this shard's slot of the replicated [W] cohort mask — the
            # SAME draw the simulated engine makes (see module docstring
            # for why the slot comes from the widx input, not axis_index)
            avail = participation_mask(strategy, comm.step,
                                       W)[jnp.squeeze(widx, 0)]

        ckey = None
        if strategy.compressor == "randk":
            # this shard's slot of the round's [W] selection keys — the SAME
            # draw the simulated engine makes (slot from the widx input, not
            # axis_index; see the participation note above)
            ckey = compressor_keys(strategy.compressor_seed, comm.step,
                                   W)[jnp.squeeze(widx, 0)]

        wu = worker_update(grads, qhat, eps_hat_sq, clock, bits_spent,
                           comm.theta_hist, lr_k, W, strategy, step=comm.step,
                           lazy_m=lazy, R_anchor_m=R_anchor, params=params,
                           grad_stale_m=grads_stale, avail_m=avail,
                           error_m=error, ckey_m=ckey, defense_m=defense)
        (delta_masked, qhat_new, eps_hat_sq_new, clock_new, uploaded,
         bits_m, width_m) = (wu.delta_masked, wu.qhat_new, wu.eps_hat_sq_new,
                             wu.clock_new, wu.uploaded, wu.bits_m, wu.width_m)

        if wire == "float":
            agg_delta = jax.tree.map(
                functools.partial(jax.lax.psum, axis_name=wa), delta_masked)
        else:
            # a defense-rejected upload is masked off the wire exactly like
            # a lazy skip (its bits_m still pay: the payload was sent)
            skip = jnp.logical_not(wu.committed)
            agg_delta, _ = _packed_aggregate(
                grads, qhat, skip, strategy, wa, pspecs=grad_pspecs,
                width=width_m if strategy.adaptive else None)

        agg = jax.tree.map(lambda a, d: a.astype(jnp.float32) + d,
                           comm.server_agg, agg_delta)
        agg_store = jax.tree.map(lambda a, s: a.astype(s.dtype), agg,
                                 comm.server_agg)
        new_params, new_opt = optimizer.update(agg, opt_state, params, lr_k)
        dtheta_sq = tree_sq_norm(jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params, params))

        new_comm = CommState(
            qhat=_unsqueeze0(qhat_new),
            server_agg=agg_store,
            eps_hat_sq=eps_hat_sq_new[None],
            clocks=clock_new[None],
            bits_spent=(bits_spent + bits_m)[None],
            theta_hist=push_history(comm.theta_hist, dtheta_sq),
            total_bits=comm.total_bits + jax.lax.psum(bits_m, wa),
            total_uploads=comm.total_uploads
            + jax.lax.psum(uploaded.astype(jnp.int32), wa),
            step=comm.step + 1,
            lazy=_unsqueeze0(wu.lazy_new),
            R_anchor=wu.R_anchor_new[None],
            svrg=svrg_new,
            error=_unsqueeze0(wu.error_new),
            defense=_unsqueeze0(wu.defense_new),
        )
        metrics = StepMetrics(
            loss=jax.lax.psum(loss, wa),
            uploads=jax.lax.psum(uploaded.astype(jnp.int32), wa),
            bits=jax.lax.psum(bits_m, wa),
            grad_sq=tree_sq_norm(agg),
        )
        return new_params, new_opt, new_comm, metrics

    # --- partial-auto shard_map: manual over worker axes, auto over model ---
    worker_set = set(worker_axes)

    def step(state: TrainState, batch):
        comm = state.comm
        specs_comm = CommState(
            qhat=jax.tree.map(lambda _: P(wa), comm.qhat),
            server_agg=jax.tree.map(lambda _: P(), comm.server_agg),
            eps_hat_sq=P(wa), clocks=P(wa), bits_spent=P(wa), theta_hist=P(),
            total_bits=P(), total_uploads=P(), step=P(),
            lazy=jax.tree.map(lambda _: P(wa), comm.lazy),
            R_anchor=P(wa),
            svrg=jax.tree.map(lambda _: P(wa), comm.svrg),
            error=jax.tree.map(lambda _: P(wa), comm.error),
            defense=jax.tree.map(lambda _: P(wa), comm.defense),
        )
        sm = compat.shard_map(
            sharded_step, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), state.params),
                      jax.tree.map(lambda _: P(), state.opt_state),
                      specs_comm,
                      jax.tree.map(lambda _: P(wa), batch),
                      P(wa)),
            out_specs=(jax.tree.map(lambda _: P(), state.params),
                       jax.tree.map(lambda _: P(), state.opt_state),
                       specs_comm,
                       StepMetrics(P(), P(), P(), P())),
            axis_names=worker_set, check_vma=False)
        new_params, new_opt, new_comm, metrics = sm(
            state.params, state.opt_state, comm, batch,
            jnp.arange(W, dtype=jnp.int32))
        return TrainState(new_params, new_opt, new_comm, state.step + 1), metrics

    # introspection: the backend the sharded step actually runs after the
    # version-gated resolve (tests pin the honor-vs-downgrade behavior)
    step.wire_backend = get_backend(strategy.wire_backend).name
    return step


# ---------------------------------------------------------------------------
# State construction (concrete and abstract/dry-run variants)
# ---------------------------------------------------------------------------

def init_train_state(key, cfg: ModelConfig, mesh, strategy: StrategyConfig,
                     optimizer: Optimizer, worker_axes):
    from repro.models import init_params
    from repro.core.strategy import init_comm_state
    params = init_params(key, cfg)
    opt_state = optimizer.init(params)
    W = n_workers_of(mesh, worker_axes)
    comm = init_comm_state(params, W, strategy)
    return TrainState(params, opt_state, comm,
                      jnp.zeros((), jnp.int32))


def train_state_specs(cfg: ModelConfig, mesh, strategy: StrategyConfig,
                      optimizer: Optimizer, worker_axes):
    """Abstract TrainState of ShapeDtypeStructs with NamedShardings attached —
    lowers without allocating (the multi-pod dry-run path)."""
    from repro.models import init_params
    from repro.core.strategy import init_comm_state

    W = n_workers_of(mesh, worker_axes)
    wa = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    model_size = mesh.shape["model"]

    params_abs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_pspecs(cfg, params_abs, model_size)
    opt_abs = jax.eval_shape(optimizer.init, params_abs)
    # optimizer state mirrors params (AdamState carries extra scalars)
    def opt_spec(leaf_path, leaf):
        return _match_param_spec(leaf, params_abs, pspecs)
    # params passed as a real argument (not closed over) so init_comm_state
    # sees tracers: the lasg_ps theta_last snapshot reads template *values*
    comm_abs = jax.eval_shape(lambda p: init_comm_state(p, W, strategy),
                              params_abs)

    def shard(abs_leaf, spec):
        return jax.ShapeDtypeStruct(abs_leaf.shape, abs_leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    params_s = jax.tree.map(shard, params_abs, pspecs)

    def opt_state_specs(opt_abs):
        # match each opt leaf to the param it mirrors by shape, else replicate
        shape2spec = {}
        for leaf, spec in zip(jax.tree.leaves(params_abs), jax.tree.leaves(pspecs)):
            shape2spec.setdefault(leaf.shape, spec)
        return jax.tree.map(
            lambda l: shard(l, shape2spec.get(l.shape, P())), opt_abs)

    opt_s = opt_state_specs(opt_abs)

    def comm_leaf_spec(qh_leaf, pspec):
        return shard(qh_leaf, P(*((wa,) + tuple(pspec))))

    def lazy_specs(lz):
        # pytree fields mirror the param pytree with a leading worker dim
        # (like qhat); scalar estimator fields shard over the worker axis
        def tree_specs(t):
            return None if t is None else jax.tree.map(comm_leaf_spec, t, pspecs)
        return lz._replace(
            grad_ema=tree_specs(lz.grad_ema),
            stat_ema=shard(lz.stat_ema, P(wa)),
            stat_count=shard(lz.stat_count, P(wa)),
            sigma_hat_sq=shard(lz.sigma_hat_sq, P(wa)),
            theta_last=tree_specs(lz.theta_last),
        )

    def svrg_specs(sv):
        # both fields mirror the param pytree with a leading worker dim
        def tree_specs(t):
            return None if t is None else jax.tree.map(comm_leaf_spec, t, pspecs)
        return SvrgState(theta_anchor=tree_specs(sv.theta_anchor),
                         mu_anchor=tree_specs(sv.mu_anchor))

    def error_specs(er):
        # the EF residual mirrors qhat: param pytree + leading worker dim
        if er.residual is None:
            return ErrorState(None)
        return ErrorState(residual=jax.tree.map(comm_leaf_spec,
                                                er.residual, pspecs))

    def defense_specs(ds):
        # all-scalar per-worker fields: gate EMA + debias count + rejects
        if ds.norm_ema is None:
            return DefenseState(None, None, None)
        return DefenseState(norm_ema=shard(ds.norm_ema, P(wa)),
                            norm_count=shard(ds.norm_count, P(wa)),
                            rejects=shard(ds.rejects, P(wa)))

    comm_s = CommState(
        qhat=jax.tree.map(comm_leaf_spec, comm_abs.qhat, pspecs),
        server_agg=jax.tree.map(lambda l, sp: shard(l, sp),
                                comm_abs.server_agg, pspecs),
        eps_hat_sq=shard(comm_abs.eps_hat_sq, P(wa)),
        clocks=shard(comm_abs.clocks, P(wa)),
        bits_spent=shard(comm_abs.bits_spent, P(wa)),
        theta_hist=shard(comm_abs.theta_hist, P()),
        total_bits=shard(comm_abs.total_bits, P()),
        total_uploads=shard(comm_abs.total_uploads, P()),
        step=shard(comm_abs.step, P()),
        lazy=lazy_specs(comm_abs.lazy),
        R_anchor=shard(comm_abs.R_anchor, P(wa)),
        svrg=svrg_specs(comm_abs.svrg),
        error=error_specs(comm_abs.error),
        defense=defense_specs(comm_abs.defense),
    )
    step_s = shard(jax.ShapeDtypeStruct((), jnp.int32), P())
    return TrainState(params_s, opt_s, comm_s, step_s)


def _match_param_spec(leaf, params_abs, pspecs):
    for pl, sp in zip(jax.tree.leaves(params_abs), jax.tree.leaves(pspecs)):
        if pl.shape == leaf.shape:
            return sp
    return P()


def batch_specs(cfg: ModelConfig, mesh, batch: int, seq: int, worker_axes=None):
    """Global batch sharded over *all* data-parallel axes (regardless of LAQ
    worker granularity — hierarchical mode keeps per-pod data parallelism
    under GSPMD)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    s = NamedSharding(mesh, P(dp, None))
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32, sharding=s),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32, sharding=s),
    }
