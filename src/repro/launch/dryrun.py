import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import/init: jax locks the device count on first use.

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).
#
# For each combination this builds the sharded step function (train / prefill /
# decode per the shape's kind), lowers it with ShapeDtypeStruct stand-ins (no
# allocation), compiles it for the production mesh, and records
# memory_analysis / cost_analysis / collective-bytes roofline terms to JSON.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
#     PYTHONPATH=src python -m repro.launch.dryrun --multi-pod ...

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import for_shape, get_config
from repro.core.strategy import StrategyConfig
from repro.models import n_active_params, n_params
from repro.models.config import INPUT_SHAPES
from repro.optim import adamw, sgd

from .mesh import make_production_mesh, worker_axes_of
from .roofline import Roofline, analyze, memory_analysis_dict
from .serve import make_decode_step, make_prefill_step, serve_specs
from .train import batch_specs, make_train_step, train_state_specs


def _build_lowered(cfg, shape, mesh, strategy, opt, wire, hierarchical,
                   multi_pod, microbatch=1):
    """Lower the shape-appropriate step for ``cfg`` on ``mesh``."""
    if shape.kind == "train":
        wa = worker_axes_of(mesh, hierarchical=hierarchical)
        step = make_train_step(cfg, mesh, strategy, opt, lr=1e-3,
                               worker_axes=wa, wire=wire,
                               microbatch=microbatch)
        state_s = train_state_specs(cfg, mesh, strategy, opt, wa)
        batch_s = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        return jax.jit(step).lower(state_s, batch_s)
    if shape.kind == "prefill":
        params_s, _, _ = serve_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        pf = make_prefill_step(cfg, max_len=shape.seq_len)
        dp = ("pod", "data") if multi_pod else ("data",)
        from jax.sharding import NamedSharding, PartitionSpec as P
        tokens_s = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32,
            sharding=NamedSharding(mesh, P(dp, None)))
        return jax.jit(pf).lower(params_s, tokens_s)
    params_s, cache_s, tokens_s = serve_specs(
        cfg, mesh, shape.global_batch, shape.seq_len)
    return jax.jit(make_decode_step(cfg)).lower(params_s, cache_s, tokens_s)


def _probe_costs(compiled):
    from .roofline import collective_bytes
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    cb = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            cb)


def roofline_probe(cfg, shape, mesh, strategy, opt, wire, hierarchical,
                   multi_pod, microbatch=1):
    """Exact roofline terms via reduced-depth UNROLLED lowerings.

    XLA cost_analysis counts a while/scan body once regardless of trip count,
    so the scanned full-depth compile under-reports per-layer work.  We lower
    unrolled variants at L = 0 (isolates the fixed embed/head/LAQ part
    exactly, nearly-free compile) and L = unit (one whole period; unit =
    attn_every for hybrids) and extrapolate: total = fixed + n_units*per_unit.
    Exact for homogeneous stacks since per-layer cost is index-independent.
    """
    unit = cfg.attn_every if cfg.arch_type == "hybrid" else 1
    costs = []
    for L in (0, unit):
        cfg_L = dataclasses.replace(cfg, n_layers=L, scan_layers=False)
        lowered = _build_lowered(cfg_L, shape, mesh, strategy, opt, wire,
                                 hierarchical, multi_pod, microbatch)
        costs.append(_probe_costs(lowered.compile()))
    (f0, b0, c0), (f1, b1, c1) = costs
    n_units = cfg.n_layers // unit
    def extrap(fixed, v1):
        per = max(v1 - fixed, 0.0)
        return fixed + n_units * per
    flops = extrap(f0, f1)
    hbm = extrap(b0, b1)
    coll = {k: extrap(c0[k], c1[k]) for k in c0}
    return flops, hbm, coll


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            strategy_kind: str = "laq", bits: int = 4, wire: str = "float",
            hierarchical: bool = False, optimizer_name: str = "sgd",
            mesh=None, probe: bool = True, cfg_overrides: dict | None = None,
            strategy_overrides: dict | None = None, microbatch: int = 1,
            tag: str = "") -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = for_shape(get_config(arch), shape)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    strategy = StrategyConfig(kind=strategy_kind, bits=bits,
                              per_leaf_radius=True,
                              **(strategy_overrides or {}))
    opt = {"sgd": sgd, "adamw": adamw}[optimizer_name]()

    if shape.kind == "train":
        model_flops = 6.0 * n_active_params(cfg) * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active_params(cfg) * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active_params(cfg) * shape.global_batch

    # 1) full-depth scanned lowering: THE compile proof + memory analysis
    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, strategy, opt, wire,
                             hierarchical, multi_pod, microbatch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = memory_analysis_dict(compiled)

    # 2) roofline terms from unrolled reduced-depth probes (exact counts)
    rf = analyze(compiled, n_devices=n_dev, model_flops_global=model_flops)
    if probe:
        flops, hbm, coll = roofline_probe(cfg, shape, mesh, strategy, opt,
                                          wire, hierarchical, multi_pod,
                                          microbatch)
        rf = Roofline(flops=flops, hbm_bytes=hbm,
                      coll_bytes=float(sum(coll.values())),
                      coll_breakdown={k: int(v) for k, v in coll.items()},
                      model_flops=model_flops / n_dev)
    rec = {
        "tag": tag,
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names])),
        "multi_pod": multi_pod,
        "strategy": strategy_kind, "bits": bits, "wire": wire,
        "hierarchical": hierarchical,
        "n_params": n_params(cfg), "n_active_params": n_active_params(cfg),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": rf.to_dict(),
        "ok": True,
    }
    print(f"[dryrun] {tag or 'baseline'} {arch} x {shape_name} mesh={rec['mesh']} "
          f"strategy={strategy_kind}/{wire} OK "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s) "
          f"bottleneck={rf.bottleneck} "
          f"t=({rf.t_compute*1e3:.1f}, {rf.t_memory*1e3:.1f}, "
          f"{rf.t_collective*1e3:.1f}) ms  useful={rf.useful_flops_ratio:.2f}", flush=True)
    if mem:
        print(f"         memory_analysis: {mem}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each pair")
    ap.add_argument("--strategy", default="laq", choices=["gd", "qgd", "lag", "laq"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--wire", default="float", choices=["float", "packed"])
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled roofline probes (compile proof only)")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args()

    fast_order = ["mamba2-130m", "stablelm-1.6b", "musicgen-medium",
                  "qwen3-moe-30b-a3b", "yi-6b", "zamba2-2.7b", "qwen3-8b",
                  "yi-9b", "phi3.5-moe-42b-a6.6b", "chameleon-34b"]
    archs = fast_order if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(
                        arch, shape, multi_pod=mp,
                        strategy_kind=args.strategy, bits=args.bits,
                        wire=args.wire, hierarchical=args.hierarchical,
                        optimizer_name=args.optimizer,
                        probe=not args.no_probe))
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "ok": False,
                                    "error": f"{type(e).__name__}: {e}"})
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(r.get("ok") for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} combinations lowered+compiled -> {args.out}")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
