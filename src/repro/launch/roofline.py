"""Roofline terms from a compiled (dry-run) executable.

TPU v5e constants (the TARGET hardware; the container runs CPU):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.

``cost_analysis`` yields per-device HLO FLOPs / bytes; collective bytes are
not in cost_analysis, so we parse the *post-SPMD-partitioning* HLO text
(``compiled.as_text()``) and sum the output bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (shapes in
that module are already per-partition).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[512,128]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[(\.]")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape = m.group(1) or m.group(2)
        out[m.group(3)] += _shape_bytes(shape)
    return out


@dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0     # 6*N*D useful-model flops per device

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": dict(self.coll_breakdown),
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, *, n_devices: int, model_flops_global: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):          # older API returns one dict per device
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    cb = collective_bytes(compiled.as_text())
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(cb.values())),
        coll_breakdown=cb,
        model_flops=model_flops_global / n_devices,
    )


def memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out
