from .mesh import make_production_mesh, make_test_mesh, n_workers_of, worker_axes_of
from .publish import ReplicaFleet, publish_trajectory, trainer_rounds
