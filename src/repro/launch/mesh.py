"""Production meshes.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def worker_axes_of(mesh, *, hierarchical: bool = False):
    """LAQ worker granularity: flat = every data shard is a worker;
    hierarchical = pods are workers (intra-pod full-precision psum)."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod",) if hierarchical else ("pod", "data")
    return ("data",)


def n_workers_of(mesh, worker_axes) -> int:
    n = 1
    for a in worker_axes:
        n *= mesh.shape[a]
    return n
