"""Publisher driver: a `RoundEngine` trainer feeding a replica fleet.

Glue between the jitted training loop and the host-side publishing state
machine of :mod:`repro.core.replica`: step the engine round by round,
offer each new iterate to the publisher, and deliver whatever it emits
(delta / resync / nothing) to a fleet of bounded-staleness replicas.

The fleet models pull-side heterogeneity with the exact
`DelayedParticipation` idiom (``d_r = r mod (max_delay + 1)``): replica
``r`` applies at round ``k`` the message the publisher cut at round
``k - d_r`` — a slow edge PoP is a *delayed subscriber*, not a different
protocol.  Messages ride a ring of the last ``max_delay + 1`` rounds; a
replica whose message "has not arrived yet" ages exactly like a lazy
skip, so freshness accounting (``rounds_behind``) is uniform across
laziness and transport delay.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Optional

import jax

from repro.core.replica import (PublishConfig, PublisherState, apply_message,
                                init_replica, publish, staleness_drift)


class ReplicaFleet:
    """``n_replicas`` bounded-staleness subscribers of one publisher.

    ``max_delay=0`` is a synchronous fleet (every replica applies each
    message the round it is cut); otherwise replica ``r`` lags by the
    fixed transport delay ``r mod (max_delay + 1)`` rounds.
    """

    def __init__(self, params0, n_replicas: int, cfg: PublishConfig, *,
                 max_delay: int = 0):
        assert n_replicas >= 1 and max_delay >= 0
        self.cfg = cfg
        self.delays = [r % (max_delay + 1) for r in range(n_replicas)]
        self.replicas = [init_replica(params0) for _ in range(n_replicas)]
        # ring of the last max_delay+1 cut messages; index -1-d is the
        # message from d rounds ago (None until it exists)
        self._ring = deque([None] * (max_delay + 1), maxlen=max_delay + 1)

    def deliver(self, msg) -> None:
        """One fleet round: enqueue the freshly cut ``msg`` (may be None)
        and let every replica apply the message its delay entitles it to."""
        self._ring.append(msg)
        ring = list(self._ring)
        for r, d in enumerate(self.delays):
            arrived = ring[-1 - d] if d < len(ring) else None
            self.replicas[r] = apply_message(self.replicas[r], arrived,
                                             self.cfg)

    def freshness(self):
        """Per-replica ``rounds_behind`` (transport delay + laziness)."""
        return [st.rounds_behind for st in self.replicas]

    def max_drift(self, params) -> float:
        return max(staleness_drift(params, st) for st in self.replicas)


def trainer_rounds(engine, params0, steps: int) -> Iterable:
    """Yield the trainer's params iterate after each of ``steps`` rounds.

    The engine round is jitted once and stepped eagerly (the publisher is
    a host-side state machine between rounds, so a `lax.scan` over the
    whole run is not an option here — and the per-round host hop is the
    realistic serving deployment anyway).
    """
    step = jax.jit(engine.round)
    carry = engine.init_carry(params0)
    for _ in range(steps):
        carry, _ = step(carry, None)
        yield carry[0]


def publish_trajectory(params_iter: Iterable, cfg: PublishConfig,
                       state: PublisherState, *,
                       fleet: Optional[ReplicaFleet] = None):
    """Run the publisher over a parameter trajectory.

    Returns ``(final_state, rows)`` where ``rows`` has one dict per round:
    what was sent (``kind`` in push/resync/skip), cumulative bits, and —
    when a ``fleet`` is attached — its freshness and worst-case drift
    against the live trainer params.
    """
    rows = []
    for params in params_iter:
        msg, state = publish(cfg, state, params)
        if msg is None:
            kind = "skip"
        elif hasattr(msg, "payloads"):
            kind = "push"
        else:
            kind = "resync"
        row = {"round": state.seq, "kind": kind,
               "bits_sent": state.bits_sent, "n_pushes": state.n_pushes,
               "n_resyncs": state.n_resyncs,
               "pub_rounds_behind": state.rounds_behind}
        if fleet is not None:
            fleet.deliver(msg)
            row["fleet_max_behind"] = max(fleet.freshness())
            row["fleet_max_drift"] = fleet.max_drift(params)
        rows.append(row)
    return state, rows
