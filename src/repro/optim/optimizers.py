"""Pure-JAX optimizers (no external deps).

The LAQ strategies produce an *aggregated gradient*; these optimizers consume
it.  The paper's own method is plain GD (``sgd``); ``adamw`` keeps a float32
master copy of bf16 parameters (standard mixed-precision practice), so the
optimizer state is where full precision lives.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = object


class Optimizer(NamedTuple):
    init: Callable      # params -> opt_state
    update: Callable    # (grads, opt_state, params, lr) -> (new_params, new_state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state
    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, new_m)
        return new_p, new_m
    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    master: Pytree      # float32 master weights
    count: jax.Array


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
            master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        c = state.count + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        def step(w, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return w - lr * (upd + weight_decay * w)
        master = jax.tree.map(step, state.master, mu, nu)
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
        return new_params, AdamState(mu, nu, master, c)
    return Optimizer(init, update)
