"""jax version-compatibility shims (validated on 0.4.37 and the current API).

Two API moves are papered over here so the rest of the codebase can be
written against the modern surface:

* ``shard_map`` — new jax exposes ``jax.shard_map(f, mesh=..., in_specs=...,
  out_specs=..., axis_names=..., check_vma=...)``; 0.4.x only has
  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
  check_rep=..., auto=...)``.  The shim translates ``axis_names`` (the set of
  *manual* axes) into ``auto`` (its complement over the mesh) and ``check_vma``
  into ``check_rep``.

* ``get_abstract_mesh`` — new jax tracks an ambient abstract mesh
  (``jax.sharding.get_abstract_mesh``) that sharding-constraint helpers query
  for axis names.  0.4.x has no such tracking, so the shim maintains its own
  thread-local ambient-mesh record that the compat ``shard_map`` installs
  around the wrapped function, so code *inside* a shard_map region can see
  the mesh axes on both versions.  Deliberately NOT installed: the physical
  mesh context (``with mesh:``) — it would let bare-``PartitionSpec``
  ``with_sharding_constraint`` trace inside the manual region, but on 0.4.x
  those constraints lower without the manual-subgroup marking and the XLA
  spmd partitioner check-fails (hard abort).  Sharding-pin helpers
  (``models.layers.maybe_constrain``) already treat an unresolvable
  constraint as a no-op, which is the correct 0.4.x degradation: the pins
  are a collective-payload perf optimization, not a correctness requirement.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import NamedTuple

import jax

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_NATIVE_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


class AmbientMesh(NamedTuple):
    """Duck-typed stand-in for jax's AbstractMesh (names + sizes only)."""
    axis_names: tuple
    axis_sizes: tuple


_tls = threading.local()

# The 0.4.x SPMD partitioner check-fails (hard abort: "Check failed:
# sharding.IsManualSubgroup()") on XLA control flow (scan/while/cond) whose
# body touches values sharded over the *auto* axes of a partially-manual
# shard_map.  Model code must statically unroll such loops there.
SUPPORTS_LOOPS_OVER_AUTO_AXES = _HAS_NATIVE_SHARD_MAP

# Likewise, inside a partially-manual shard_map the 0.4.x partitioner only
# lowers ``psum``: ``all_gather``/``ppermute`` hit the same hard abort, and
# the psum-emulation escape hatch (one-hot by ``axis_index``) dies earlier
# still because ``axis_index`` lowers to a PartitionId instruction the
# partitioner rejects.  Payload-exchange code must degrade to psum-only
# transport on 0.4.x (see launch/train.py ``_packed_aggregate``).
SUPPORTS_PARTIAL_AUTO_COLLECTIVES = _HAS_NATIVE_SHARD_MAP


def needs_loop_unrolling() -> bool:
    """True while tracing inside a compat shard_map region on a jax whose
    partitioner aborts on loops over auto-axis-sharded values (0.4.x).

    Model code consults this to swap ``lax.scan`` for a static python loop
    (layer stack, flash-attention kv chunks, microbatch accumulation).  Known
    limitation: the Mamba2 sequence scan and the hybrid stack's ``lax.cond``
    have no unrolled variant, so SSM/hybrid architectures still cannot run
    under partial-auto shard_map on 0.4.x.
    """
    return (not SUPPORTS_LOOPS_OVER_AUTO_AXES
            and getattr(_tls, "mesh", None) is not None)


def get_abstract_mesh():
    """The ambient mesh (axis_names/axis_sizes), or None when there isn't one.

    Native on new jax; on 0.4.x, the record installed by the compat
    :func:`shard_map` wrapper, falling back to the physical mesh context
    (``with mesh:``) when one is active.
    """
    if _HAS_NATIVE_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    am = getattr(_tls, "mesh", None)
    if am is not None:
        return am
    try:
        phys = jax._src.mesh.thread_resources.env.physical_mesh
        if phys.axis_names:
            return AmbientMesh(tuple(phys.axis_names),
                               tuple(phys.shape[a] for a in phys.axis_names))
    except Exception:
        pass
    return None


@contextlib.contextmanager
def _ambient(mesh):
    prev = getattr(_tls, "mesh", None)
    _tls.mesh = AmbientMesh(tuple(mesh.axis_names),
                            tuple(mesh.shape[a] for a in mesh.axis_names))
    try:
        yield
    finally:
        _tls.mesh = prev


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """Version-portable shard_map with the new-API argument names.

    ``axis_names`` is the set of axes the function is *manual* over; all other
    mesh axes stay auto (GSPMD).  ``axis_names=None`` means manual over every
    axis (both APIs' default).
    """
    if _HAS_NATIVE_SHARD_MAP:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    all_axes = set(mesh.axis_names)
    manual = all_axes if axis_names is None else set(axis_names)
    auto = frozenset(all_axes - manual)

    @functools.wraps(f)
    def wrapped(*args, **kw):
        with _ambient(mesh):
            return f(*args, **kw)

    return _shard_map(wrapped, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=auto)
