"""jax version-compatibility layer — native ≥ 0.5 paths primary, 0.4.x shims
kept for one more release.

As of the jax ≥ 0.5 migration the **native API surface is the primary
path**: ``jax.shard_map`` (full partial-auto support in the partitioner),
``jax.sharding.get_abstract_mesh``, and Pallas lowering inside
partially-manual ``shard_map`` regions.  Everything in this module that
exists to paper over 0.4.x is a *deprecated legacy shim*, gated on
``ON_LEGACY_JAX`` and scheduled for deletion when the 0.4.37 CI pin drops
(one release of overlap; the CI matrix runs both pins until then):

* ``shard_map`` — new jax exposes ``jax.shard_map(f, mesh=..., in_specs=...,
  out_specs=..., axis_names=...)``; 0.4.x only has
  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
  check_rep=..., auto=...)``.  The shim translates ``axis_names`` (the set of
  *manual* axes) into ``auto`` (its complement over the mesh) and ``check_vma``
  into ``check_rep``.

* ``get_abstract_mesh`` — new jax tracks an ambient abstract mesh
  (``jax.sharding.get_abstract_mesh``) that sharding-constraint helpers query
  for axis names.  0.4.x has no such tracking, so the shim maintains its own
  thread-local ambient-mesh record that the compat ``shard_map`` installs
  around the wrapped function, so code *inside* a shard_map region can see
  the mesh axes on both versions.  Deliberately NOT installed: the physical
  mesh context (``with mesh:``) — it would let bare-``PartitionSpec``
  ``with_sharding_constraint`` trace inside the manual region, but on 0.4.x
  those constraints lower without the manual-subgroup marking and the XLA
  spmd partitioner check-fails (hard abort).  Sharding-pin helpers
  (``models.layers.maybe_constrain``) already treat an unresolvable
  constraint as a no-op, which is the correct 0.4.x degradation: the pins
  are a collective-payload perf optimization, not a correctness requirement.

* the three 0.4.x partial-auto partitioner limits (loops/collectives over
  auto axes, PartitionId, Pallas lowering) and their degradations
  (``needs_loop_unrolling`` static unrolls, the local-decode+psum packed
  wire, the reference-wire downgrade in ``launch/train.py``).  On ≥ 0.5
  every capability flag below is True and none of the degradations is ever
  consulted — they are dead code on the primary path.
"""
from __future__ import annotations

import contextlib
import functools
import logging
import threading
from typing import NamedTuple

import jax

logger = logging.getLogger("repro.compat")


def _parse_version(v: str) -> tuple:
    parts = []
    for tok in v.split(".")[:3]:
        num = ""
        for ch in tok:
            if not ch.isdigit():
                break
            num += ch
        parts.append(int(num) if num else 0)
    return tuple(parts)


JAX_VERSION = _parse_version(jax.__version__)

# The migration gate: jax < 0.5 runs the *legacy* partial-auto partitioner
# whose limits the degradations below paper over.  ≥ 0.5 is the primary,
# shim-free path.  (Kept alongside the hasattr probes because a bare
# version check is what the deprecation schedule is written against.)
ON_LEGACY_JAX = JAX_VERSION < (0, 5)

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_NATIVE_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


class AmbientMesh(NamedTuple):
    """Duck-typed stand-in for jax's AbstractMesh (names + sizes only)."""
    axis_names: tuple
    axis_sizes: tuple


_tls = threading.local()

# --------------------------------------------------------------------------
# Capability flags.  All True on ≥ 0.5 (the primary path); the False
# branches are the deprecated 0.4.x degradations, kept for one release.
# --------------------------------------------------------------------------

# The 0.4.x SPMD partitioner check-fails (hard abort: "Check failed:
# sharding.IsManualSubgroup()") on XLA control flow (scan/while/cond) whose
# body touches values sharded over the *auto* axes of a partially-manual
# shard_map.  Model code must statically unroll such loops there.
SUPPORTS_LOOPS_OVER_AUTO_AXES = not ON_LEGACY_JAX

# Likewise, inside a partially-manual shard_map the 0.4.x partitioner only
# lowers ``psum``: ``all_gather``/``ppermute`` hit the same hard abort, and
# the psum-emulation escape hatch (one-hot by ``axis_index``) dies earlier
# still because ``axis_index`` lowers to a PartitionId instruction the
# partitioner rejects.  Payload-exchange code must degrade to psum-only
# transport on 0.4.x (see launch/train.py ``_packed_aggregate``).
SUPPORTS_PARTIAL_AUTO_COLLECTIVES = not ON_LEGACY_JAX

# The 0.4.x partial-auto partitioner cannot lower ``pallas_call`` (nor the
# flat reshapes of auto-axis-sharded leaves the fused wire's per-leaf
# kernels need), so the sharded step must downgrade any non-reference wire
# backend there.  ≥ 0.5 lowers both, so the requested backend is honored
# (launch/train.py resolve_wire_backend).
SUPPORTS_PALLAS_PARTIAL_AUTO = not ON_LEGACY_JAX


def in_legacy_partial_auto_region() -> bool:
    """True while tracing inside a compat shard_map region on 0.4.x — the
    scope where ALL the legacy partitioner limits apply (loops/collectives/
    Pallas over auto axes, and non-manual sharding constraints, which
    hard-abort ``spmd_partitioner.cc`` the same way).  Constant False on
    ≥ 0.5; scheduled for deletion with the 0.4.37 CI pin."""
    return ON_LEGACY_JAX and getattr(_tls, "mesh", None) is not None


def needs_loop_unrolling() -> bool:
    """True while tracing inside a compat shard_map region on a jax whose
    partitioner aborts on loops over auto-axis-sharded values (0.4.x only —
    constant False on ≥ 0.5, where this helper is scheduled for deletion).

    Model code consults this to swap ``lax.scan`` for a static python loop
    (layer stack, flash-attention kv chunks, microbatch accumulation, and
    the Mamba2 inter-chunk recurrence).  Perf-only sharding constraints
    (``moe._shard_experts``) no-op in the same scope via
    :func:`in_legacy_partial_auto_region`.
    """
    return (not SUPPORTS_LOOPS_OVER_AUTO_AXES
            and getattr(_tls, "mesh", None) is not None)


_warned: set = set()
_warned_lock = threading.Lock()


def warn_once(key: str, message: str) -> bool:
    """Log ``message`` at WARNING level the first time ``key`` is seen in
    this process (degradation notices must not spam a jitted training loop).
    Returns True iff the warning was emitted now."""
    with _warned_lock:
        if key in _warned:
            return False
        _warned.add(key)
    logger.warning(message)
    return True


def get_abstract_mesh():
    """The ambient mesh (axis_names/axis_sizes), or None when there isn't one.

    Native on ≥ 0.5; on 0.4.x, the record installed by the compat
    :func:`shard_map` wrapper, falling back to the physical mesh context
    (``with mesh:``) when one is active.
    """
    if _HAS_NATIVE_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    am = getattr(_tls, "mesh", None)
    if am is not None:
        return am
    try:
        phys = jax._src.mesh.thread_resources.env.physical_mesh
        if phys.axis_names:
            return AmbientMesh(tuple(phys.axis_names),
                               tuple(phys.shape[a] for a in phys.axis_names))
    except Exception:
        pass
    return None


@contextlib.contextmanager
def _ambient(mesh):
    prev = getattr(_tls, "mesh", None)
    _tls.mesh = AmbientMesh(tuple(mesh.axis_names),
                            tuple(mesh.shape[a] for a in mesh.axis_names))
    try:
        yield
    finally:
        _tls.mesh = prev


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """Version-portable shard_map with the new-API argument names.

    ``axis_names`` is the set of axes the function is *manual* over; all other
    mesh axes stay auto (GSPMD).  ``axis_names=None`` means manual over every
    axis (both APIs' default).
    """
    if _HAS_NATIVE_SHARD_MAP:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        try:
            return jax.shard_map(f, check_vma=check_vma, **kwargs)
        except TypeError:
            # 0.5/0.6-era native shard_map spells the replication check
            # ``check_rep``; same semantics
            return jax.shard_map(f, check_rep=bool(check_vma), **kwargs)

    # ---- deprecated 0.4.x shim (delete with the 0.4.37 CI pin) ----------
    from jax.experimental.shard_map import shard_map as _shard_map
    all_axes = set(mesh.axis_names)
    manual = all_axes if axis_names is None else set(axis_names)
    auto = frozenset(all_axes - manual)

    @functools.wraps(f)
    def wrapped(*args, **kw):
        with _ambient(mesh):
            return f(*args, **kw)

    return _shard_map(wrapped, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=auto)
