"""repro: LAQ (Lazily Aggregated Quantized Gradients, NeurIPS 2019) as a
production-grade multi-pod JAX training/serving framework."""
