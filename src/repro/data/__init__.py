from .synthetic import (classification_dataset, lm_batches, lm_worker_corpus,
                        split_workers, synthetic_lm_batch)
