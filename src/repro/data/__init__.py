from .synthetic import (classification_dataset, lm_batches, split_workers,
                        synthetic_lm_batch)
