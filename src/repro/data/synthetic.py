"""Synthetic data pipelines (the container is offline; MNIST is emulated).

* ``classification_dataset`` — the paper-repro substrate: a 10-class,
  784-feature Gaussian-mixture problem with controllable class separation and
  per-worker heterogeneity (the paper studies heterogeneity in its supp.).
* ``lm_batches`` / ``synthetic_lm_batch`` — deterministic token streams for
  LM training: a Zipf-like marginal with a Markov structure so the loss has
  learnable signal, generated shard-locally from a seeded PRNG (no host I/O),
  placed onto the mesh with the right sharding.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Paper-repro: MNIST-like classification mixture
# ---------------------------------------------------------------------------

def classification_dataset(key, *, n_per_class: int = 100, n_classes: int = 10,
                           n_features: int = 784, separation: float = 2.0,
                           noise: float = 1.0):
    """Returns (X [N,F], Y one-hot [N,C]) — a linearly-separable-ish mixture."""
    kc, kx = jax.random.split(key)
    centers = separation * jax.random.normal(kc, (n_classes, n_features)) / np.sqrt(n_features)
    N = n_classes * n_per_class
    labels = jnp.tile(jnp.arange(n_classes), n_per_class)
    X = centers[labels] + noise * jax.random.normal(kx, (N, n_features)) / np.sqrt(n_features)
    Y = jax.nn.one_hot(labels, n_classes)
    return X, Y


def split_workers(X, Y, n_workers: int, *, heterogeneity: float = 0.0,
                  key: Optional[jax.Array] = None):
    """Shard a dataset over workers. heterogeneity=0 -> uniform shuffle;
    1 -> sorted by label (maximally non-iid), as in the paper's supp study."""
    N = X.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    labels = jnp.argmax(Y, -1)
    uniform = jax.random.permutation(key, N)
    sorted_idx = jnp.argsort(labels, stable=True)
    n_sorted = int(heterogeneity * N)
    idx = jnp.concatenate([sorted_idx[:n_sorted],
                           uniform[~jnp.isin(uniform, sorted_idx[:n_sorted])]])[:N]
    per = N // n_workers
    idx = idx[:per * n_workers].reshape(n_workers, per)
    return X[idx], Y[idx]


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

def synthetic_lm_batch(key, batch: int, seq: int, vocab: int):
    """Markov-ish token stream: next token depends on current (mod structure)
    plus Zipf-sampled noise — cheap, deterministic, learnable."""
    k1, k2 = jax.random.split(key)
    # Zipf marginal via inverse-CDF on uniform
    u = jax.random.uniform(k1, (batch, seq + 1))
    zipf = jnp.minimum((1.0 / jnp.maximum(u, 1e-6)) ** 0.7, float(vocab)) - 1
    base = zipf.astype(jnp.int32) % vocab
    # Markov mixing: with prob .5, token t+1 = f(token t)
    mix = jax.random.bernoulli(k2, 0.5, (batch, seq + 1))
    rolled = (base * 31 + 7) % vocab
    stream = jnp.where(mix, rolled, base)
    return {"tokens": stream[:, :-1], "targets": stream[:, 1:]}


def lm_worker_corpus(seed: int, n_workers: int, n_local: int, seq: int,
                     vocab: int) -> dict:
    """Per-worker LM token shards for the simulated engine: ``{"tokens",
    "targets"}`` of shape ``[W, N_local, S]``, worker ``m``'s shard drawn
    from its own ``fold_in(seed, m)`` stream of the same Markov-Zipf
    process — deterministic, no host I/O, and heterogeneous across workers
    (each worker sees a different slice of the distribution, the federated
    LM setting the LAQ skip criterion is supposed to exploit)."""
    key0 = jax.random.PRNGKey(seed)

    def worker(m):
        return synthetic_lm_batch(jax.random.fold_in(key0, m),
                                  n_local, seq, vocab)

    return jax.vmap(worker)(jnp.arange(n_workers))


def lm_batches(seed: int, batch: int, seq: int, vocab: int,
               sharding=None) -> Iterator[dict]:
    """Infinite iterator of device-placed LM batches."""
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        b = synthetic_lm_batch(key, batch, seq, vocab)
        if sharding is not None:
            b = jax.device_put(b, sharding)
        yield b
        step += 1
