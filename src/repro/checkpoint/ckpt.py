"""Pytree checkpointing: flatten key-paths -> npz (single-host).

Stores dtype-preserving arrays under stable '/'-joined key paths plus a
step counter.  LAQ's CommState checkpoints the same way — it is a pytree —
so a resumed run continues with the same server aggregate and worker clocks.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, tree, step: int) -> None:
    flat = {}
    def record(kp, leaf):
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            flat["BF16::" + _path_str(kp)] = arr.astype(np.float32)
        else:
            flat[_path_str(kp)] = arr
        return leaf
    jax.tree_util.tree_map_with_path(record, tree)
    flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, tree_template) -> Tuple[object, int]:
    """Restores into the structure (and shardings) of ``tree_template``.

    The template must match the checkpoint structurally: a key present in
    the file but absent from the template (or vice versa) raises ``KeyError``
    naming every offender — the common cause is restoring into a CommState
    whose optional fields (lazy / svrg / error / defense) were configured
    differently from the run that saved (see docs/robustness.md on watchdog
    escalation, which migrates such carries field-by-field instead).
    """
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    if "__step__" not in data:
        raise KeyError(f"{path}: not a repro checkpoint (no __step__ entry)")
    step = int(data.pop("__step__"))
    used = set()

    def restore(kp, leaf):
        key = _path_str(kp)
        if "BF16::" + key in data:
            key = "BF16::" + key
            arr = data[key].astype(jax.numpy.bfloat16)
        elif key in data:
            arr = data[key]
        else:
            raise KeyError(
                f"{path}: template leaf '{key}' missing from checkpoint — "
                f"saved run used a different CommState configuration")
        used.add(key)
        if arr.shape != leaf.shape:
            raise ValueError(
                f"{path}: shape mismatch at '{key}': checkpoint "
                f"{arr.shape} vs template {leaf.shape}")
        sharding = getattr(leaf, "sharding", None)
        return jax.device_put(arr, sharding) if sharding else jax.numpy.asarray(arr)

    restored = jax.tree_util.tree_map_with_path(restore, tree_template)
    extra = sorted(set(data) - used)
    if extra:
        raise KeyError(
            f"{path}: checkpoint entries not consumed by the template: "
            f"{extra} — saved run carried state the template lacks")
    return restored, step
