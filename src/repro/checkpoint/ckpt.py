"""Pytree checkpointing: flatten key-paths -> npz (single-host).

Stores dtype-preserving arrays under stable '/'-joined key paths plus a
step counter.  LAQ's CommState checkpoints the same way — it is a pytree —
so a resumed run continues with the same server aggregate and worker clocks.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, tree, step: int) -> None:
    flat = {}
    def record(kp, leaf):
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            flat["BF16::" + _path_str(kp)] = arr.astype(np.float32)
        else:
            flat[_path_str(kp)] = arr
        return leaf
    jax.tree_util.tree_map_with_path(record, tree)
    flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, tree_template) -> Tuple[object, int]:
    """Restores into the structure (and shardings) of ``tree_template``."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = int(data.pop("__step__"))

    def restore(kp, leaf):
        key = _path_str(kp)
        if "BF16::" + key in data:
            arr = data["BF16::" + key].astype(jax.numpy.bfloat16)
        else:
            arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        sharding = getattr(leaf, "sharding", None)
        return jax.device_put(arr, sharding) if sharding else jax.numpy.asarray(arr)

    return jax.tree_util.tree_map_with_path(restore, tree_template), step
