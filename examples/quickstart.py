"""Quickstart: LAQ vs GD/QGD/LAG on the paper's logistic-regression setting.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline result in ~a minute on CPU: LAQ reaches the
same accuracy as GD with ~100x fewer communication rounds and ~1000x fewer
transmitted bits (Table 2 of the paper).
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (BitSchedule, CriterionConfig, StrategyConfig,
                        run_gradient_based)
from repro.data import classification_dataset, split_workers

M = 10                                   # workers, as in the paper


def main():
    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=60)
    workers = split_workers(X, Y, M)
    N = X.shape[0]

    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * 0.01 * jnp.sum(params["w"] ** 2)) / N

    params0 = {"w": jnp.zeros((10, 784))}
    crit = CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)

    # a-laq: per-worker per-round width from the innovation-radius decay.
    # Scale-free thresholds: fractions of the bootstrap-round radius
    # (core/adaptive.py "rel" mode), so the same tuple works on any
    # workload — no absolute radii to tune per problem.
    alaq_schedule = BitSchedule(kind="radius", grid=(2, 4, 8),
                                threshold_mode="rel", thresholds=(0.05, 0.5))
    configs = [(kind, StrategyConfig(kind=kind, bits=4, criterion=crit))
               for kind in ("gd", "qgd", "lag", "laq")]
    configs.append(("a-laq", StrategyConfig(kind="laq", criterion=crit,
                                            bit_schedule=alaq_schedule)))

    print(f"{'method':6s} {'final loss':>12s} {'rounds':>8s} {'bits':>12s} {'accuracy':>9s}")
    for kind, cfg in configs:
        r = run_gradient_based(loss_fn, params0, workers, cfg,
                               steps=500, alpha=2.0)
        pred = jnp.argmax(X @ r.params["w"].T, -1)
        acc = float(jnp.mean((pred == jnp.argmax(Y, -1)).astype(jnp.float32)))
        print(f"{kind:6s} {float(r.loss[-1]):12.6f} {int(r.cum_uploads[-1]):8d} "
              f"{float(r.cum_bits[-1]):12.3e} {acc:9.4f}")


if __name__ == "__main__":
    main()
