"""End-to-end LM training driver with LAQ gradient exchange.

    # smoke (default): ~7M params, 8 forced host devices, mesh (4 data, 2 model)
    PYTHONPATH=src python examples/train_lm.py --steps 50

    # stochastic lazy rule + gradient accumulation (the AccumulatingSource
    # fold shared with core/engine.py; 2 sequential microbatches per worker)
    PYTHONPATH=src python examples/train_lm.py --steps 20 --strategy slaq --accum 2

    # error-feedback top-k compression (pure data-parallel mesh, float wire)
    PYTHONPATH=src python examples/train_lm.py --steps 20 --strategy ef

    # ~100M-parameter run (slow on CPU; the shape MaxText-style frameworks
    # train per-host before scaling the same code to the pod mesh)
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Demonstrates the full production path: sharded data pipeline -> partial-auto
shard_map LAQ train step (per-worker quantize + skip + explicit aggregation
collective) -> optimizer -> checkpoint, with bits/rounds telemetry.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.core.strategy import StrategyConfig
from repro.data import lm_batches
from repro.launch.mesh import n_workers_of
from repro.launch.train import (init_train_state, make_train_step,
                                train_state_specs)
from repro.models.config import ModelConfig
from repro.optim import adamw

PRESETS = {
    "smoke": ModelConfig(name="lm-smoke", arch_type="dense", n_layers=4,
                         d_model=256, vocab=4096, n_heads=4, n_kv_heads=2,
                         head_dim=64, d_ff=1024, q_chunk=128, kv_chunk=64),
    "100m": ModelConfig(name="lm-100m", arch_type="dense", n_layers=12,
                        d_model=768, vocab=32768, n_heads=12, n_kv_heads=4,
                        head_dim=64, d_ff=2048, q_chunk=256, kv_chunk=128),
}

# CLI strategy -> StrategyConfig.  The first four are the paper's
# deterministic kinds; the rest exercise the stochastic levers on the LM
# step: slaq = variance-aware LASG-WK rule, wk2 = same-sample noise-free
# rule (second backprop), svrg = variance-reduced local gradients, ef =
# error-feedback top-k compression (float wire, data-parallel mesh).
STRATEGIES = ("gd", "qgd", "lag", "laq", "slaq", "wk2", "svrg", "ef")


def build_strategy(name: str, bits: int) -> StrategyConfig:
    base = dict(bits=bits, per_leaf_radius=True)
    if name in ("gd", "qgd", "lag", "laq"):
        return StrategyConfig(kind=name, **base)
    if name == "slaq":
        return StrategyConfig(kind="laq", lazy_rule="lasg_wk", **base)
    if name == "wk2":
        return StrategyConfig(kind="laq", lazy_rule="lasg_wk2", **base)
    if name == "svrg":
        return StrategyConfig(kind="laq", grad_mode="svrg", **base)
    if name == "ef":
        return StrategyConfig(kind="laq", compressor="topk",
                              compressor_k=0.05, error_feedback=True, **base)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="laq", choices=list(STRATEGIES))
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--accum", type=int, default=1,
                    help="sequential microbatches per worker (gradient "
                         "accumulation; activation memory / accum)")
    ap.add_argument("--wire", default="float", choices=["float", "packed"])
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    strategy = build_strategy(args.strategy, args.bits)
    if strategy.compressed or strategy.error_feedback:
        # the sparse pipeline needs a pure data-parallel mesh + float wire
        # (launch/train.py); all eight host devices become LAQ workers
        mesh_shape = (8, 1)
        assert args.wire == "float", "--strategy ef requires --wire float"
    else:
        mesh_shape = (4, 2)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    wa = ("data",)
    W = n_workers_of(mesh, wa)
    assert args.batch % W == 0, f"--batch must be divisible by {W} workers"
    assert (args.batch // W) % args.accum == 0, \
        "--accum must divide the per-worker batch"
    opt = adamw(weight_decay=0.01)

    state = init_train_state(jax.random.PRNGKey(0), cfg, mesh, strategy, opt, wa)
    n_par = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model={cfg.name} params={n_par/1e6:.1f}M strategy={args.strategy}"
          f"/{args.wire} accum={args.accum} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    specs = train_state_specs(cfg, mesh, strategy, opt, wa)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding), state, specs)

    step_fn = jax.jit(make_train_step(cfg, mesh, strategy, opt, lr=args.lr,
                                      worker_axes=wa, wire=args.wire,
                                      microbatch=args.accum))
    batches = lm_batches(0, args.batch, args.seq, cfg.vocab,
                         sharding=NamedSharding(mesh, P("data", None)))

    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, next(batches))
        if i % 10 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss={float(m.loss):7.4f} "
                  f"uploads={int(m.uploads)} cum_bits={float(state.comm.total_bits):.3e} "
                  f"tok/s={tok_s:,.0f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, jax.device_get(state.params), args.steps)
        print(f"checkpoint -> {args.ckpt}")
    skip_rate = 1 - float(state.comm.total_uploads) / (W * args.steps)
    print(f"done: final loss {float(m.loss):.4f}; worker-upload skip rate "
          f"{skip_rate:.1%}; total wire bits {float(state.comm.total_bits):.3e} "
          f"(dense GD would be {32 * n_par * W * args.steps:.3e})")


if __name__ == "__main__":
    main()
