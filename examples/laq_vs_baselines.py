"""Full method comparison (gradient + stochastic families) with CSV export —
the paper's Figures 4/7 as data.

    PYTHONPATH=src python examples/laq_vs_baselines.py --out /tmp/laq_curves.csv
"""
import argparse
import csv
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (CriterionConfig, StrategyConfig, run_gradient_based,
                        run_stochastic)
from repro.data import classification_dataset, split_workers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="laq_curves.csv")
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=60)
    workers = split_workers(X, Y, 10)
    N = X.shape[0]

    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * 0.01 * jnp.sum(params["w"] ** 2)) / N

    p0 = {"w": jnp.zeros((10, 784))}
    crit = CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)

    rows = [("family", "method", "iteration", "loss", "rounds", "bits")]
    for kind in ("gd", "qgd", "lag", "laq"):
        r = run_gradient_based(loss_fn, p0, workers,
                               StrategyConfig(kind=kind, bits=4, criterion=crit),
                               steps=args.steps, alpha=2.0)
        for i in range(0, args.steps, 5):
            rows.append(("gradient", kind, i, float(r.loss[i]),
                         int(r.cum_uploads[i]), float(r.cum_bits[i])))
        print(f"[gradient]   {kind:5s} loss={float(r.loss[-1]):.6f} "
              f"rounds={int(r.cum_uploads[-1]):6d} bits={float(r.cum_bits[-1]):.3e}")
    # participation family (PR-5 round engine, core/engine.py): the same
    # deterministic LAQ under client sampling (each round only a Bernoulli-p
    # cohort of workers is reachable; masked workers are accounted exactly
    # like lazy skips) and under bounded-delay staleness (worker m computes
    # at theta^{k - (m mod 5)})
    base = StrategyConfig(kind="laq", bits=4, criterion=crit)
    participation = [
        ("laq_p0.5", base._replace(participation="bernoulli",
                                   participation_p=0.5)),
        ("laq_p0.2", base._replace(participation="bernoulli",
                                   participation_p=0.2)),
        ("laq_delay4", base._replace(participation="delay", max_delay=4)),
    ]
    for label, cfg in participation:
        r = run_gradient_based(loss_fn, p0, workers, cfg,
                               steps=args.steps, alpha=2.0)
        for i in range(0, args.steps, 5):
            rows.append(("participation", label, i, float(r.loss[i]),
                         int(r.cum_uploads[i]), float(r.cum_bits[i])))
        print(f"[particip.]  {label:10s} loss={float(r.loss[-1]):.6f} "
              f"rounds={int(r.cum_uploads[-1]):6d} bits={float(r.cum_bits[-1]):.3e}")
    # stochastic family: the slaq_* kinds differ only in the lazy rule
    # (core/lazy_rules.py) — eq. 7a replayed on noise vs the variance-aware
    # LASG-WK / same-sample LASG-WK2 / LASG-PS criteria; slaq_vr keeps the
    # 7a rule but feeds it svrg-corrected gradients (grad_mode="svrg"),
    # which removes the variance floor instead of skipping around it
    scfg = StrategyConfig(kind="laq", bits=3, criterion=crit)
    stochastic = [(k, k, scfg) for k in
                  ("sgd", "qsgd", "ssgd", "slaq", "slaq_wk", "slaq_wk2",
                   "slaq_ps")]
    stochastic.append(("slaq_vr", "slaq",
                       scfg._replace(grad_mode="svrg", svrg_period=10)))
    for label, kind, cfg in stochastic:
        r = run_stochastic(loss_fn, p0, workers, kind, steps=args.steps,
                           alpha=0.5, batch=30, bits=3, density=0.1,
                           laq_cfg=cfg)
        for i in range(0, args.steps, 5):
            rows.append(("stochastic", label, i, float(r.loss[i]),
                         int(r.cum_uploads[i]), float(r.cum_bits[i])))
        print(f"[stochastic] {label:8s} loss={float(r.loss[-1]):.6f} "
              f"rounds={int(r.cum_uploads[-1]):6d} bits={float(r.cum_bits[-1]):.3e}")

    with open(args.out, "w", newline="") as f:
        csv.writer(f).writerows(rows)
    print(f"curves -> {args.out}")


if __name__ == "__main__":
    main()
