"""Batched serving demo: prefill a prompt batch, decode with the sharded
KV cache (sequence dim on the model axis — flash-decode style).

    PYTHONPATH=src python examples/serve_lm.py --tokens 32

Timing discipline (the two historical serve-path sins, both fixed here):

* **warmup before t0** — the first call to each jit pays XLA compilation
  (seconds, vs ms of compute); both jits and the cache reshard are run
  once before any timer starts, so the reported numbers are steady-state;
* **donated decode cache** — the decode jit donates its cache argument
  (``jit_serve``): without donation every decoded token copies the full
  KV cache.  The greedy argmax is folded into the jitted step, and the
  timed decode loop runs under ``jax.transfer_guard("disallow")`` to
  *prove* no per-step host round-trip survives.

With ``--publish-rounds N`` the demo becomes the lazy-replica serving
loop (docs/serving.md): a `RoundEngine` LAQ trainer steps the micro LM
while a publisher pushes quantized parameter deltas to a bounded-
staleness replica fleet, and replica 0's serving weights decode traffic
on the mesh between rounds — the weights refresh over the packed wire,
not via checkpoint reloads.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.launch.serve import jit_serve
from repro.models import cache_pspecs, init_params, param_pspecs


def shard_cache(cfg, cache, mesh):
    cspecs = cache_pspecs(cfg, cache, mesh.shape["data"], mesh.shape["model"])
    return jax.device_put(cache, jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), cspecs))


def serve_session(cfg, mesh, params, prompts, n_tokens: int, *,
                  prefill_fn, decode_fn, quiet: bool = False):
    """Steady-state timed prefill + greedy decode.  Both jits must already
    be warm; the decode cache is donated, so the cache from the timed
    prefill is consumed by the loop."""
    batch, prompt_len = prompts.shape

    t0 = time.time()
    tok, cache = prefill_fn(params, prompts)
    cache = shard_cache(cfg, cache, mesh)
    jax.block_until_ready((tok, cache))
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    # any hidden host transfer in the decode step (implicit np conversion,
    # un-jitted argmax, debug print) now raises instead of silently
    # serializing the loop
    with jax.transfer_guard("disallow"):
        for _ in range(n_tokens - 1):
            tok, cache = decode_fn(params, cache, tok)
            out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    if not quiet:
        print(f"prefill: {batch}x{prompt_len} in {t_prefill*1e3:.0f} ms "
              f"({batch*prompt_len/t_prefill:,.0f} tok/s)")
        print(f"decode: {n_tokens} steps x batch {batch} in "
              f"{t_decode*1e3:.0f} ms ({batch*n_tokens/t_decode:,.0f} tok/s)"
              f"  pos={int(cache['pos'])}")
    ids = jnp.concatenate(out, axis=1)
    return ids, t_prefill, t_decode


def run_serve(args):
    cfg = smoke_config(get_config(args.arch))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    max_len = args.prompt_len + args.tokens

    params = init_params(jax.random.PRNGKey(0), cfg)
    pspecs = param_pspecs(cfg, params, mesh.shape["model"])
    params = jax.device_put(params, jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), pspecs))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    prompts = jax.device_put(prompts, NamedSharding(mesh, P("data", None)))

    prefill_fn, decode_fn = jit_serve(cfg, max_len)

    # warmup: compile both jits + the reshard OUTSIDE any timer.  The
    # warmup decode call donates (consumes) the warmup cache, leaving the
    # timed session to its own fresh prefill.
    tok, cache = prefill_fn(params, prompts)
    cache = shard_cache(cfg, cache, mesh)
    jax.block_until_ready(decode_fn(params, cache, tok))

    ids, _, _ = serve_session(cfg, mesh, params, prompts, args.tokens,
                              prefill_fn=prefill_fn, decode_fn=decode_fn)
    print("sample continuation ids[0]:", ids[0, :16].tolist())


def run_publish(args):
    """Trainer publishes quantized deltas; replica 0 serves the traffic."""
    from repro.core import (CriterionConfig, EtaSchedule, PublishConfig,
                            RoundEngine, StrategyConfig)
    from repro.core.engine import AccumulatingSource
    from repro.core.replica import publish, init_publisher
    from repro.data import lm_worker_corpus
    from repro.launch.publish import ReplicaFleet
    from repro.models import lm_worker_loss
    from repro.models.config import ModelConfig

    # the PR-8 micro LM + LAQ recipe (b=8 dense grid, 1/t stepsize): the
    # served model IS the trained model
    cfg = ModelConfig(name="lm-micro", arch_type="dense", n_layers=2,
                      d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                      head_dim=16, d_ff=64, q_chunk=16, kv_chunk=8,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    W = 4
    scfg = StrategyConfig(kind="laq", bits=8, per_leaf_radius=True,
                          criterion=CriterionConfig(D=10, xi=0.08, t_bar=100),
                          eta_schedule=EtaSchedule(kind="inv_t", t0=30.0))
    engine = RoundEngine(
        AccumulatingSource(lm_worker_loss(cfg, W),
                           lm_worker_corpus(0, W, 16, 16, cfg.vocab),
                           deterministic=True, accum=2, scale=1.0),
        scfg, alpha=0.5)

    pcfg = PublishConfig(bits=4, threshold=args.threshold,
                         max_staleness=args.max_staleness,
                         wire_backend="reference")
    params0 = init_params(jax.random.PRNGKey(0), cfg)
    pub = init_publisher(params0, pcfg)
    fleet = ReplicaFleet(params0, args.replicas, pcfg,
                         max_delay=args.max_delay)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    max_len = args.prompt_len + args.tokens
    pspecs = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                          param_pspecs(cfg, params0, mesh.shape["model"]))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    prompts = jax.device_put(prompts, NamedSharding(mesh, P("data", None)))
    prefill_fn, decode_fn = jit_serve(cfg, max_len)

    def serve_from(replica_params, quiet):
        sparams = jax.device_put(replica_params, pspecs)
        return serve_session(cfg, mesh, sparams, prompts, args.tokens,
                             prefill_fn=prefill_fn, decode_fn=decode_fn,
                             quiet=quiet)

    serve_from(fleet.replicas[0].params, True)     # warmup both jits

    step = jax.jit(engine.round)
    carry = engine.init_carry(params0)
    print(f"round {'kind':>6s} {'loss':>8s} {'Mbits':>8s} "
          f"{'behind':>6s} {'drift':>9s} {'decode tok/s':>12s}")
    for k in range(args.publish_rounds):
        carry, rec = step(carry, None)
        msg, pub = publish(pcfg, pub, carry[0])
        fleet.deliver(msg)
        kind = ("skip" if msg is None
                else "push" if hasattr(msg, "payloads") else "resync")
        _, _, t_dec = serve_from(fleet.replicas[0].params, True)
        print(f"{k:5d} {kind:>6s} {float(rec[0]):8.4f} "
              f"{pub.bits_sent/1e6:8.3f} {max(fleet.freshness()):6d} "
              f"{fleet.max_drift(carry[0]):9.2e} "
              f"{args.batch*args.tokens/t_dec:12,.0f}")
    print(f"published {pub.n_pushes} deltas + {pub.n_resyncs} resyncs over "
          f"{args.publish_rounds} rounds ({pub.bits_sent/1e6:.3f} Mbits "
          f"incl. init snapshot)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--publish-rounds", type=int, default=0,
                    help="train+publish this many rounds (0 = plain serve)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--max-staleness", type=int, default=8)
    ap.add_argument("--max-delay", type=int, default=1)
    args = ap.parse_args()
    if args.publish_rounds > 0:
        run_publish(args)
    else:
        run_serve(args)


if __name__ == "__main__":
    main()
