"""Batched serving demo: prefill a prompt batch, decode with the sharded
KV cache (sequence dim on the model axis — flash-decode style).

    PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.models import cache_pspecs, init_params, param_pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    max_len = args.prompt_len + args.tokens

    params = init_params(jax.random.PRNGKey(0), cfg)
    pspecs = param_pspecs(cfg, params, mesh.shape["model"])
    params = jax.device_put(params, jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), pspecs))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    prompts = jax.device_put(prompts, NamedSharding(mesh, P("data", None)))

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    cspecs = cache_pspecs(cfg, cache, mesh.shape["data"], mesh.shape["model"])
    cache = jax.device_put(cache, jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), cspecs))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:,.0f} tok/s)")

    out = []
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32) % cfg.vocab
    t0 = time.time()
    for _ in range(args.tokens):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32) % cfg.vocab
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {args.tokens} steps x batch {args.batch} in {dt*1e3:.0f} ms "
          f"({args.batch*args.tokens/dt:,.0f} tok/s)  pos={int(cache['pos'])}")
    ids = jnp.concatenate(out, axis=1)
    print("sample continuation ids[0]:", ids[0, :16].tolist())


if __name__ == "__main__":
    main()
