"""Participation/staleness frontier: bits-and-uploads-to-loss under partial
participation (client sampling p in {1.0, 0.5, 0.2}) and bounded-delay
staleness (D in {0, 4}) — the scenarios the PR-5 round engine added
(core/engine.py participation models; LAG's heterogeneous-worker setting).

Substrate: the paper's logistic-regression mixture, deterministic full
gradients (paper Table 2 regime), so every effect measured here is the
participation model's, not minibatch noise.  Two LAQ criterion settings:

* the paper criterion (xi = 0.8/10) — LAQ's **skip-dominated** regime
  (~2% of upload opportunities used).  Here the skip rule *absorbs*
  sampling: workers that were sampled out simply upload at their next
  available round, so the upload count barely moves with p while the loss
  target is still reached — lazy aggregation composes with availability
  instead of stacking losses.
* a 10x stricter criterion (xi = 0.08/10) — the **communication-rich**
  regime where LAQ uploads often.  There sampling prunes upload
  opportunities directly: p = 0.5 reaches the target with roughly half
  the uploads of p = 1.0 (the acceptance headline), exactly like the
  dense QGD reference whose uploads are p-scaled by construction.

Headline claims checked:

* LAQ reaches the dense-QGD loss target at every p and at D=4 (bounded
  staleness and client sampling do not break the skip criterion);
* at matched p, LAQ needs fewer wire bits than QGD (the skip rule keeps
  paying under sampling);
* dense uploads are p-scaled (QGD at p=0.5 uses ~half the uploads of
  p=1.0), and so are communication-rich LAQ's;
* sampling never *increases* LAQ communication;
* D=4 staleness costs at most a modest bits-to-target factor;
* Markov burst-churn (PR-7: long ON/OFF availability streaks at matched
  mean availability p=0.5) still reaches the target with essentially the
  same total bits as full participation — in the skip-dominated regime
  workers that return from an OFF streak just resume the lazy schedule.

    PYTHONPATH=src python -m benchmarks.participation_frontier
"""
from __future__ import annotations

import numpy as np

from repro.core import CriterionConfig, StrategyConfig, run_gradient_based

from .common import PAPER_CRITERION, logreg_init, logreg_loss, make_dataset

STEPS = 400
BITS = 4
ALPHA = 2.0
P_GRID = (1.0, 0.5, 0.2)
DELAY = 4
TARGET_TOL = 1.05     # reach within 5% of the dense-QGD floor
RICH_CRITERION = CriterionConfig(D=10, xi=0.08 / 10, t_bar=100)


def first_reach(result, target: float):
    """(uploads, bits) at the first *sustained* crossing (see
    lasg_frontier.first_reach for why first-touch would be an artifact)."""
    loss = np.asarray(result.loss)
    trailing_max = np.maximum.accumulate(loss[::-1])[::-1]
    reached = trailing_max <= target
    if not reached.any():
        return None
    k = int(np.argmax(reached))
    return int(result.cum_uploads[k]), float(result.cum_bits[k])


def run(out_rows, results):
    workers, full = make_dataset()
    loss_fn = logreg_loss(full[0].shape[0])
    laq = StrategyConfig(kind="laq", bits=BITS, criterion=PAPER_CRITERION)
    qgd = laq._replace(kind="qgd")
    rich = laq._replace(criterion=RICH_CRITERION)

    def sampled(cfg, p):
        if p >= 1.0:
            return cfg
        return cfg._replace(participation="bernoulli", participation_p=p)

    cfgs = {}
    for p in P_GRID:
        cfgs[f"laq_p{p}"] = sampled(laq, p)
        cfgs[f"qgd_p{p}"] = sampled(qgd, p)
    for p in (1.0, 0.5):
        cfgs[f"laq_rich_p{p}"] = sampled(rich, p)
    cfgs[f"laq_d{DELAY}"] = laq._replace(participation="delay",
                                         max_delay=DELAY)
    # Markov burst-churn vs i.i.d. sampling at matched mean availability
    # p=0.5: long ON/OFF streaks (sojourn=8) vs the memoryless chain
    # (sojourn = 1/(1-p) = 2 makes the stationary draw i.i.d. Bernoulli).
    cfgs["laq_mkv_burst"] = laq._replace(participation="markov",
                                         participation_p=0.5,
                                         markov_sojourn=8.0)
    cfgs["laq_mkv_iid"] = laq._replace(participation="markov",
                                       participation_p=0.5,
                                       markov_sojourn=2.0)
    runs = {name: run_gradient_based(loss_fn, logreg_init(), workers, cfg,
                                     steps=STEPS, alpha=ALPHA)
            for name, cfg in cfgs.items()}

    target = TARGET_TOL * float(runs["qgd_p1.0"].loss[-1])

    frontier = {}
    for name, r in runs.items():
        at = first_reach(r, target)
        frontier[name] = dict(
            final_loss=float(r.loss[-1]),
            total_uploads=int(r.cum_uploads[-1]),
            total_bits=float(r.cum_bits[-1]),
            uploads_to_target=None if at is None else at[0],
            bits_to_target=None if at is None else at[1])
        out_rows.append((f"participation_{name}", float(r.cum_bits[-1]),
                         f"loss={frontier[name]['final_loss']:.4f};"
                         f"to_target={at}"))
    results["participation_frontier"] = dict(target_loss=target, **frontier)

    def to_target(name, field="bits_to_target"):
        v = frontier[name][field]
        return np.inf if v is None else v

    up_ratio_qgd = (to_target("qgd_p0.5", "uploads_to_target")
                    / to_target("qgd_p1.0", "uploads_to_target"))
    up_ratio_rich = (to_target("laq_rich_p0.5", "uploads_to_target")
                     / to_target("laq_rich_p1.0", "uploads_to_target"))
    checks = {
        "LAQ reaches the target at every p and at D=4": all(
            frontier[n]["bits_to_target"] is not None
            for n in ("laq_p1.0", "laq_p0.5", "laq_p0.2", f"laq_d{DELAY}",
                      "laq_rich_p1.0", "laq_rich_p0.5")),
        "bits-to-target: LAQ < QGD at p=1.0":
            to_target("laq_p1.0") < to_target("qgd_p1.0"),
        "bits-to-target: LAQ < QGD at p=0.5 (skip rule composes)":
            to_target("laq_p0.5") < to_target("qgd_p0.5"),
        "bits-to-target: LAQ < QGD at p=0.2":
            to_target("laq_p0.2") < to_target("qgd_p0.2"),
        "dense uploads are p-scaled: QGD p=0.5 uses ~half of p=1.0":
            0.4 <= up_ratio_qgd <= 0.6,
        "comm-rich LAQ p=0.5 reaches target with ~half the uploads":
            0.35 <= up_ratio_rich <= 0.7,
        "sampling never increases LAQ communication":
            frontier["laq_p0.2"]["total_uploads"]
            <= frontier["laq_p0.5"]["total_uploads"]
            <= frontier["laq_p1.0"]["total_uploads"],
        f"bounded staleness D={DELAY} costs <= 1.5x bits-to-target":
            to_target(f"laq_d{DELAY}") <= 1.5 * to_target("laq_p1.0"),
        "markov churn (bursty and memoryless) reaches the target":
            frontier["laq_mkv_burst"]["bits_to_target"] is not None
            and frontier["laq_mkv_iid"]["bits_to_target"] is not None,
        "churn costs <= 1.05x full-participation LAQ bits (skips absorb it)":
            frontier["laq_mkv_burst"]["total_bits"]
            <= 1.05 * frontier["laq_p1.0"]["total_bits"]
            and frontier["laq_mkv_iid"]["total_bits"]
            <= 1.05 * frontier["laq_p1.0"]["total_bits"],
    }
    results["participation_frontier/claims"] = checks
    return checks


def main():
    out_rows, results = [], {}
    checks = run(out_rows, results)
    f = results["participation_frontier"]
    print(f"target loss = {f['target_loss']:.4f} "
          f"({TARGET_TOL}x dense-QGD floor, b={BITS}, alpha={ALPHA})")
    print(f"{'run':14s} {'final loss':>11s} {'uploads':>8s} {'bits':>11s} "
          f"{'up@tgt':>7s} {'bits@tgt':>11s}")
    for name, row in f.items():
        if name == "target_loss":
            continue
        ut, bt = row["uploads_to_target"], row["bits_to_target"]
        print(f"{name:14s} {row['final_loss']:11.5f} "
              f"{row['total_uploads']:8d} {row['total_bits']:11.3e} "
              f"{(str(ut) if ut is not None else 'never'):>7s} "
              f"{(f'{bt:.3e}' if bt is not None else 'never'):>11s}")
    ok = True
    for k, v in checks.items():
        print(f"[{'PASS' if v else 'FAIL'}] {k}")
        ok &= bool(v)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
