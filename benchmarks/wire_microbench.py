"""Reference-vs-fused wire pipeline microbenchmark (the perf trajectory).

Times one worker's full send-side pipeline — radius reduction, quantize,
pack, dequantized delta/q_new, and both skip-criterion moments — through
each wire backend (core/wire.py) at several gradient sizes, and emits
``BENCH_wire.json`` at the repo root so per-PR regressions are visible (CI
runs the ``--tiny`` variant and uploads the JSON as an artifact).

Two framings per size, both recorded:

* **pipeline (staged)** — the headline comparison: each pipeline executed
  as its kernel stages, every stage individually jit-compiled (so Python
  dispatch overhead is identical on both sides and the measured gap is
  kernel count + materialized intermediates, not eager-mode overhead).
  The reference path runs its 8 elementwise stages (diff, inf-norm, codes,
  delta, q_new, err_sq, innovation_sq, pack) as separate compiled kernels
  with materialized intermediates — the multi-kernel execution the fused
  design removes; the fused path runs its two passes (absmax;
  quantize+pack+moments).  This is the framing that transfers to TPU,
  where the stages are distinct XLA kernels and the fused passes are the
  Pallas kernels in kernels/quant_pack.py.
* **whole-jit** — both backends wrapped in a single jit: on CPU, XLA's
  monolithic loop fusion absorbs the staging difference and the two run at
  parity (recorded so the staged speedup can't be mistaken for a
  whole-program CPU claim).

The sweep counts in the JSON are derived from the stage/pass lists the
bench actually executes, not hardcoded — adding a pass to either pipeline
changes the recorded number (and fails the <= 2 check for the fused path).

Every row also carries the lowering the fused backend actually took
(``fused_lowering``: "pallas" off-CPU, "jnp-flat" on CPU) and flat
``roofline_*`` terms from launch/roofline.py: compiled cost-analysis
FLOPs / HBM bytes / collective bytes of the fused whole-jit pipeline
against the TPU v5e roofline constants, the binding term, the roofline
bound in microseconds, and the achieved fraction of that bound
(bound / measured whole-jit time — nominal on CPU, where the constants
describe the target part, meaningful on it).

An adaptive row (bit_schedule grid, width selected by onehot) rides the
largest size so the width-grid-unrolled pass-2 kernel shows up in the
trajectory next to its 8-stage staged counterpart.

    PYTHONPATH=src python -m benchmarks.wire_microbench [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.adaptive import (dequantize_dynamic, quantize_dynamic,
                                 tau_of_selection)
from repro.core.quantize import (dequantize_innovation, pack_codes,
                                 quantize_codes)
# _fused_leaf_jnp / _fused_leaf_adaptive_jnp are the CPU lowerings of the
# pass-2 kernels; the bench jits each as one unit per pass, mirroring the
# Pallas kernel structure
from repro.core.wire import (FusedWire, _fused_leaf_adaptive_jnp,
                             _fused_leaf_jnp, get_backend)
from repro.launch import roofline

SIZES = [1 << 14, 1 << 17, 1 << 20]
TINY_SIZES = [1 << 12]
EXTRA_BITS_AT_LARGEST = (2, 8)
REPS = 20
GRID = (2, 4, 8)          # adaptive row: bit_schedule grid ...
ADAPTIVE_SEL = 1          # ... with b = GRID[1] = 4 selected (matches the
                          # fixed-width default, so the rows are comparable)

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_wire.json"))


def _inputs(n, seed=0):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n,), jnp.float32) * 2
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return g, qh


def _ref_stages(bits):
    """The reference pipeline as its individually-compiled kernel stages
    (each full-gradient sweep is one jit), composed exactly like
    quantize.roundtrip_parts + innovation_sq + payload."""
    return [
        jax.jit(lambda g, qh: g - qh),                             # diff
        jax.jit(lambda d: jnp.max(jnp.abs(d))),                    # R
        jax.jit(lambda d, R: quantize_codes(d, R, bits)),          # codes
        jax.jit(lambda q, R: dequantize_innovation(                # delta
            {"w": q}, {"w": R}, bits)["w"]),
        jax.jit(lambda qh, d: qh + d),                             # q_new
        jax.jit(lambda g, qn: jnp.sum(jnp.square(g - qn))),        # err_sq
        jax.jit(lambda d: jnp.sum(jnp.square(d))),                 # inn_sq
        jax.jit(lambda q: pack_codes(q, bits)),                    # payload
    ]


def _fused_passes(bits):
    """The fused pipeline's passes: one compiled kernel each (the Pallas
    kernels off-CPU; their jnp lowering, jitted per pass, on CPU)."""
    if FusedWire()._use_pallas():
        from repro.kernels import absmax, quantize_pack_fused
        return [absmax,
                lambda g, qh, R: quantize_pack_fused(g, qh, R, bits)]
    return [jax.jit(lambda g, qh: jnp.max(jnp.abs(g - qh))),
            jax.jit(lambda g, qh, R: _fused_leaf_jnp(g, qh, R, bits, True))]


def _onehot(grid, sel):
    return jnp.eye(len(grid), dtype=jnp.float32)[sel]


def _adaptive_ref_stages(grid, onehot):
    """The staged adaptive pipeline: same 8-stage shape as the fixed-width
    one, with the codes/delta stages running the grid-evaluated
    quantize_dynamic / dequantize_dynamic sweeps (core/adaptive.py)."""
    t_sel = tau_of_selection(grid, onehot)
    provision = max(grid)
    return [
        jax.jit(lambda g, qh: g - qh),                             # diff
        jax.jit(lambda d: jnp.max(jnp.abs(d))),                    # R
        jax.jit(lambda d, R: quantize_dynamic(                     # codes
            {"w": d}, {"w": R}, grid, onehot)["w"]),
        jax.jit(lambda q, R: dequantize_dynamic(                   # delta
            {"w": q}, {"w": R}, t_sel)["w"]),
        jax.jit(lambda qh, d: qh + d),                             # q_new
        jax.jit(lambda g, qn: jnp.sum(jnp.square(g - qn))),        # err_sq
        jax.jit(lambda d: jnp.sum(jnp.square(d))),                 # inn_sq
        jax.jit(lambda q: pack_codes(q, provision)),               # payload
    ]


def _adaptive_fused_passes(grid, onehot):
    """The adaptive fused pipeline: absmax + the width-grid-unrolled pass-2
    kernel (one lax.switch arm per grid width)."""
    if FusedWire()._use_pallas():
        from repro.kernels import absmax, quantize_pack_adaptive
        return [absmax,
                lambda g, qh, R: quantize_pack_adaptive(g, qh, R,
                                                        onehot, grid)]
    t_sel = tau_of_selection(grid, onehot)
    return [jax.jit(lambda g, qh: jnp.max(jnp.abs(g - qh))),
            jax.jit(lambda g, qh, R: _fused_leaf_adaptive_jnp(
                g, qh, R, grid, onehot, t_sel, True))]


def _whole_jit_adaptive(backend, grid, onehot):
    """Single-jit adaptive roundtrip through ``backend`` (radius computed
    inside the jit, like the fixed-width whole-jit rows)."""
    def fn(g, qh):
        d = g.astype(jnp.float32) - qh.astype(jnp.float32)
        R = jnp.max(jnp.abs(d))
        return backend.adaptive_roundtrip({"w": g}, {"w": qh}, {"w": d},
                                          {"w": R}, grid, onehot)
    return jax.jit(fn)


def _runners(n, bits, adaptive=False):
    """(staged_reference, staged_fused, jit_reference, jit_fused) callables
    over the same flat-leaf inputs, plus the per-pipeline sweep counts."""
    ref = get_backend("reference")
    fus = get_backend("fused")

    def tree(g, qh):
        return {"w": g}, {"w": qh}

    if adaptive:
        onehot = _onehot(GRID, ADAPTIVE_SEL)
        stages = _adaptive_ref_stages(GRID, onehot)
        passes = _adaptive_fused_passes(GRID, onehot)
        ref_jit = _whole_jit_adaptive(ref, GRID, onehot)
        fus_jit = _whole_jit_adaptive(fus, GRID, onehot)
        key = "_adaptive"
    else:
        stages = _ref_stages(bits)
        passes = _fused_passes(bits)
        ref_jit = jax.jit(lambda g, qh: ref.roundtrip(
            *tree(g, qh), bits, False, with_payload=True))
        fus_jit = jax.jit(lambda g, qh: fus.roundtrip(
            *tree(g, qh), bits, False, with_payload=True))
        key = ""

    def ref_staged(g, qh):
        s_diff, s_R, s_codes, s_delta, s_qnew, s_err, s_inn, s_pack = stages
        d = s_diff(g, qh)
        R = s_R(d)
        q = s_codes(d, R)
        delta = s_delta(q, R)
        qn = s_qnew(qh, delta)
        return s_pack(q), delta, qn, s_err(g, qn), s_inn(delta)

    def fus_staged(g, qh):
        p_absmax, p_main = passes
        return p_main(g, qh, p_absmax(g, qh))

    sweeps = {"reference" + key: len(stages), "fused" + key: len(passes)}
    return (ref_staged, fus_staged, ref_jit, fus_jit), sweeps


def _roofline_terms(n, bits, adaptive=False):
    """Flat ``roofline_*`` scalars for the fused whole-jit pipeline at
    (n, bits): compiled cost-analysis terms against the TPU v5e roofline
    constants, plus the lowering the fused backend takes on this host."""
    g, qh = _inputs(n)
    fus = get_backend("fused")
    if adaptive:
        fn = _whole_jit_adaptive(fus, GRID, _onehot(GRID, ADAPTIVE_SEL))
    else:
        fn = jax.jit(lambda g, qh: fus.roundtrip({"w": g}, {"w": qh}, bits,
                                                 False, with_payload=True))
    r = roofline.analyze(fn.lower(g, qh).compile(),
                         n_devices=1, model_flops_global=0.0)
    bound_s = max(r.t_compute, r.t_memory, r.t_collective)
    return {
        "fused_lowering": ("pallas" if FusedWire()._use_pallas()
                           else "jnp-flat"),
        "roofline_flops": r.flops,
        "roofline_hbm_bytes": r.hbm_bytes,
        "roofline_coll_bytes": r.coll_bytes,
        "roofline_t_compute_us": round(r.t_compute * 1e6, 4),
        "roofline_t_memory_us": round(r.t_memory * 1e6, 4),
        "roofline_t_collective_us": round(r.t_collective * 1e6, 4),
        "roofline_bottleneck": r.bottleneck,
        "roofline_bound_us": round(bound_s * 1e6, 4),
    }


def _time_all(n, bits, reps, best=None, adaptive=False):
    """Min-of-reps with INTERLEAVED repetitions so machine-load drift hits
    every pipeline equally.  ``best`` merges mins from earlier rounds: the
    min estimates the quiet-machine cost, so pooling reps across rounds is
    the same estimator with more samples."""
    g, qh = _inputs(n)
    fns, sweeps = _runners(n, bits, adaptive)
    for fn in fns:
        jax.tree.map(jax.block_until_ready, fn(g, qh))   # compile
    best = list(best) if best else [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.tree.map(jax.block_until_ready, fn(g, qh))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, sweeps


def bench(sizes, reps=REPS, bits=4):
    rows = []
    cases = [(n, bits, False) for n in sizes]
    if len(sizes) > 1:
        cases += [(sizes[-1], b, False) for b in EXTRA_BITS_AT_LARGEST]
    # the adaptive trajectory row: grid-unrolled pass 2 at the largest size
    cases += [(sizes[-1], GRID[ADAPTIVE_SEL], True)]
    sweeps = {}
    for n, b, adaptive in cases:
        best, sw = _time_all(n, b, reps, adaptive=adaptive)
        sweeps.update(sw)
        # headline cell: keep pooling reps until the min-cost estimate is
        # converged enough to call (noisy shared machines need more samples)
        rounds = 1
        while (not adaptive and n == max(sizes) and b == bits and rounds < 4
               and best[0] / best[1] <= 1.05):
            best, _ = _time_all(n, b, reps, best)
            rounds += 1
        r_st, f_st, r_jit, f_jit = [x * 1e6 for x in best]
        row = {"n": n, "bits": b, "adaptive": adaptive,
               "reference_us": round(r_st, 2),
               "fused_us": round(f_st, 2),
               "speedup": round(r_st / f_st, 3),
               "whole_jit_reference_us": round(r_jit, 2),
               "whole_jit_fused_us": round(f_jit, 2)}
        row.update(_roofline_terms(n, b, adaptive))
        row["roofline_frac_achieved"] = (
            round(row["roofline_bound_us"] / row["whole_jit_fused_us"], 6)
            if row["whole_jit_fused_us"] > 0 else None)
        rows.append(row)
    return rows, sweeps


def write_json(rows, sweeps, sizes, path=ROOT_JSON, tiny=False):
    largest = max(sizes)
    # the headline cell (largest size, default width); extra-bits rows stay
    # recorded as data but don't gate — their CPU margins are thinner and
    # machine noise would make the check flaky
    head = [r for r in rows if r["n"] == largest and r["bits"] == 4
            and not r["adaptive"]]
    checks = {
        # derived from the pass list the bench actually executed, not a
        # constant: a third pass in the fused pipeline fails this
        "fused_le_two_sweeps": sweeps["fused"] <= 2,
        "adaptive_fused_le_two_sweeps": (
            sweeps["fused_adaptive"] <= 2
            if "fused_adaptive" in sweeps else None),
        # dispatch overhead dominates the tiny CI-smoke size, so the
        # speedup claim is only evaluated on the full size sweep
        "fused_speedup_at_largest": (None if tiny else
                                     all(r["speedup"] > 1.0 for r in head)),
        # every row records the lowering it measured and positive compiled
        # cost-analysis terms (the roofline inputs)
        "rows_record_lowering": all(
            r.get("fused_lowering") in ("pallas", "jnp-flat") for r in rows),
        "roofline_terms_present": all(
            r.get("roofline_flops", 0) > 0 and
            r.get("roofline_hbm_bytes", 0) > 0 for r in rows),
    }
    payload = {
        "jax_backend": jax.default_backend(),
        "fused_lowering": ("pallas" if FusedWire()._use_pallas()
                           else "jnp-flat"),
        "framing": {
            "reference_us/fused_us": "pipeline executed as kernel stages, "
                                     "each stage/pass its own jit "
                                     "(8 staged kernels vs 2 fused passes)",
            "whole_jit_*": "single-jit context rows; XLA monolithic fusion "
                           "puts both at parity on CPU",
            "roofline_*": "compiled cost-analysis of the fused whole-jit "
                          "pipeline vs TPU v5e peaks (launch/roofline.py); "
                          "frac_achieved = bound/measured, nominal on CPU",
        },
        "sweeps_per_round": sweeps,
        "rows": rows,
        "checks": checks,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return checks, payload


def run(out_rows, results):
    """benchmarks/run.py entry point."""
    rows, sweeps = bench(SIZES)
    checks, payload = write_json(rows, sweeps, SIZES)
    for r in rows:
        tag = "_adaptive" if r["adaptive"] else ""
        out_rows.append((f"wire_ref_n{r['n']}_b{r['bits']}{tag}",
                         r["reference_us"], "us/round staged send-side"))
        out_rows.append((f"wire_fused_n{r['n']}_b{r['bits']}{tag}",
                         r["fused_us"],
                         f"2-pass ({r['fused_lowering']}), "
                         f"speedup x{r['speedup']}"))
    results["wire_microbench"] = payload
    return checks


def run_roofline(out_rows, results, tiny=True):
    """benchmarks/run.py entry point for the roofline-only pass (compiled
    cost analysis, no timing — deterministic, so safe to gate in CI smoke
    where the timing microbenchmarks are skipped)."""
    n = TINY_SIZES[0] if tiny else SIZES[-1]
    rows = []
    for bits, adaptive in ((4, False), (4, True)):
        r = {"n": n, "bits": bits, "adaptive": adaptive}
        r.update(_roofline_terms(n, bits, adaptive))
        rows.append(r)
        tag = "_adaptive" if adaptive else ""
        out_rows.append((f"wire_roofline_n{n}_b{bits}{tag}",
                         r["roofline_bound_us"],
                         f"{r['roofline_bottleneck']}-bound, "
                         f"{r['fused_lowering']}"))
    checks = {
        "roofline_cost_analysis_positive": all(
            r["roofline_flops"] > 0 and r["roofline_hbm_bytes"] > 0
            for r in rows),
        "roofline_bottleneck_valid": all(
            r["roofline_bottleneck"] in ("compute", "memory", "collective")
            for r in rows),
    }
    results["wire_roofline"] = {"rows": rows, "checks": checks}
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small size, few reps")
    args = ap.parse_args()
    sizes = TINY_SIZES if args.tiny else SIZES
    rows, sweeps = bench(sizes, reps=3 if args.tiny else REPS)
    checks, _ = write_json(rows, sweeps, sizes, tiny=args.tiny)
    for r in rows:
        kind = "adaptive" if r["adaptive"] else "fixed"
        print(f"n={r['n']} b={r['bits']} {kind}: staged reference "
              f"{r['reference_us']:.0f}us  fused 2-pass {r['fused_us']:.0f}us"
              f"  speedup x{r['speedup']}  (whole-jit: "
              f"{r['whole_jit_reference_us']:.0f} vs "
              f"{r['whole_jit_fused_us']:.0f}us; {r['fused_lowering']}, "
              f"roofline {r['roofline_bottleneck']}-bound "
              f"{r['roofline_bound_us']}us)")
    print(f"sweeps/round: {sweeps} -> {ROOT_JSON}")
    for k, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {k}")
    if not args.tiny and not all(checks.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
