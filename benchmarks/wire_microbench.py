"""Reference-vs-fused wire pipeline microbenchmark (the perf trajectory).

Times one worker's full send-side pipeline — radius reduction, quantize,
pack, dequantized delta/q_new, and both skip-criterion moments — through
each wire backend (core/wire.py) at several gradient sizes, and emits
``BENCH_wire.json`` at the repo root so per-PR regressions are visible (CI
runs the ``--tiny`` variant and uploads the JSON as an artifact).

Two framings per size, both recorded:

* **pipeline (staged)** — the headline comparison: each pipeline executed
  as its kernel stages, every stage individually jit-compiled (so Python
  dispatch overhead is identical on both sides and the measured gap is
  kernel count + materialized intermediates, not eager-mode overhead).
  The reference path runs its 8 elementwise stages (diff, inf-norm, codes,
  delta, q_new, err_sq, innovation_sq, pack) as separate compiled kernels
  with materialized intermediates — the multi-kernel execution the fused
  design removes; the fused path runs its two passes (absmax;
  quantize+pack+moments).  This is the framing that transfers to TPU,
  where the stages are distinct XLA kernels and the fused passes are the
  Pallas kernels in kernels/quant_pack.py.
* **whole-jit** — both backends wrapped in a single jit: on CPU, XLA's
  monolithic loop fusion absorbs the staging difference and the two run at
  parity (recorded so the staged speedup can't be mistaken for a
  whole-program CPU claim).

The sweep counts in the JSON are derived from the stage/pass lists the
bench actually executes, not hardcoded — adding a pass to either pipeline
changes the recorded number (and fails the <= 2 check for the fused path).

    PYTHONPATH=src python -m benchmarks.wire_microbench [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.quantize import (dequantize_innovation, pack_codes,
                                 quantize_codes)
# _fused_leaf_jnp is the CPU lowering of the pass-2 kernel; the bench jits
# it as one unit per pass, mirroring the Pallas kernel structure
from repro.core.wire import FusedWire, _fused_leaf_jnp, get_backend

SIZES = [1 << 14, 1 << 17, 1 << 20]
TINY_SIZES = [1 << 12]
EXTRA_BITS_AT_LARGEST = (2, 8)
REPS = 20

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_wire.json"))


def _inputs(n, seed=0):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n,), jnp.float32) * 2
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return g, qh


def _ref_stages(bits):
    """The reference pipeline as its individually-compiled kernel stages
    (each full-gradient sweep is one jit), composed exactly like
    quantize.roundtrip_parts + innovation_sq + payload."""
    return [
        jax.jit(lambda g, qh: g - qh),                             # diff
        jax.jit(lambda d: jnp.max(jnp.abs(d))),                    # R
        jax.jit(lambda d, R: quantize_codes(d, R, bits)),          # codes
        jax.jit(lambda q, R: dequantize_innovation(                # delta
            {"w": q}, {"w": R}, bits)["w"]),
        jax.jit(lambda qh, d: qh + d),                             # q_new
        jax.jit(lambda g, qn: jnp.sum(jnp.square(g - qn))),        # err_sq
        jax.jit(lambda d: jnp.sum(jnp.square(d))),                 # inn_sq
        jax.jit(lambda q: pack_codes(q, bits)),                    # payload
    ]


def _fused_passes(bits):
    """The fused pipeline's passes: one compiled kernel each (the Pallas
    kernels off-CPU; their jnp lowering, jitted per pass, on CPU)."""
    if FusedWire()._use_pallas():
        from repro.kernels import absmax, quantize_pack_fused
        return [absmax,
                lambda g, qh, R: quantize_pack_fused(g, qh, R, bits)]
    return [jax.jit(lambda g, qh: jnp.max(jnp.abs(g - qh))),
            jax.jit(lambda g, qh, R: _fused_leaf_jnp(g, qh, R, bits, True))]


def _runners(n, bits):
    """(staged_reference, staged_fused, jit_reference, jit_fused) callables
    over the same flat-leaf inputs, plus the per-pipeline sweep counts."""
    ref = get_backend("reference")
    fus = get_backend("fused")
    stages = _ref_stages(bits)
    passes = _fused_passes(bits)

    def tree(g, qh):
        return {"w": g}, {"w": qh}

    def ref_staged(g, qh):
        s_diff, s_R, s_codes, s_delta, s_qnew, s_err, s_inn, s_pack = stages
        d = s_diff(g, qh)
        R = s_R(d)
        q = s_codes(d, R)
        delta = s_delta(q, R)
        qn = s_qnew(qh, delta)
        return s_pack(q), delta, qn, s_err(g, qn), s_inn(delta)

    def fus_staged(g, qh):
        p_absmax, p_main = passes
        return p_main(g, qh, p_absmax(g, qh))

    ref_jit = jax.jit(lambda g, qh: ref.roundtrip(*tree(g, qh), bits, False,
                                                  with_payload=True))
    fus_jit = jax.jit(lambda g, qh: fus.roundtrip(*tree(g, qh), bits, False,
                                                  with_payload=True))
    sweeps = {"reference": len(stages), "fused": len(passes)}
    return (ref_staged, fus_staged, ref_jit, fus_jit), sweeps


def _time_all(n, bits, reps, best=None):
    """Min-of-reps with INTERLEAVED repetitions so machine-load drift hits
    every pipeline equally.  ``best`` merges mins from earlier rounds: the
    min estimates the quiet-machine cost, so pooling reps across rounds is
    the same estimator with more samples."""
    g, qh = _inputs(n)
    fns, sweeps = _runners(n, bits)
    for fn in fns:
        jax.tree.map(jax.block_until_ready, fn(g, qh))   # compile
    best = list(best) if best else [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.tree.map(jax.block_until_ready, fn(g, qh))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, sweeps


def bench(sizes, reps=REPS, bits=4):
    rows = []
    cases = [(n, bits) for n in sizes]
    if len(sizes) > 1:
        cases += [(sizes[-1], b) for b in EXTRA_BITS_AT_LARGEST]
    sweeps = None
    for n, b in cases:
        best, sweeps = _time_all(n, b, reps)
        # headline cell: keep pooling reps until the min-cost estimate is
        # converged enough to call (noisy shared machines need more samples)
        rounds = 1
        while (n == max(sizes) and b == bits and rounds < 4
               and best[0] / best[1] <= 1.05):
            best, _ = _time_all(n, b, reps, best)
            rounds += 1
        r_st, f_st, r_jit, f_jit = [x * 1e6 for x in best]
        rows.append({"n": n, "bits": b,
                     "reference_us": round(r_st, 2),
                     "fused_us": round(f_st, 2),
                     "speedup": round(r_st / f_st, 3),
                     "whole_jit_reference_us": round(r_jit, 2),
                     "whole_jit_fused_us": round(f_jit, 2)})
    return rows, sweeps


def write_json(rows, sweeps, sizes, path=ROOT_JSON, tiny=False):
    largest = max(sizes)
    # the headline cell (largest size, default width); extra-bits rows stay
    # recorded as data but don't gate — their CPU margins are thinner and
    # machine noise would make the check flaky
    head = [r for r in rows if r["n"] == largest and r["bits"] == 4]
    checks = {
        # derived from the pass list the bench actually executed, not a
        # constant: a third pass in the fused pipeline fails this
        "fused_le_two_sweeps": sweeps["fused"] <= 2,
        # dispatch overhead dominates the tiny CI-smoke size, so the
        # speedup claim is only evaluated on the full size sweep
        "fused_speedup_at_largest": (None if tiny else
                                     all(r["speedup"] > 1.0 for r in head)),
    }
    payload = {
        "jax_backend": jax.default_backend(),
        "fused_lowering": ("pallas" if FusedWire()._use_pallas()
                           else "jnp-flat"),
        "framing": {
            "reference_us/fused_us": "pipeline executed as kernel stages, "
                                     "each stage/pass its own jit "
                                     "(8 staged kernels vs 2 fused passes)",
            "whole_jit_*": "single-jit context rows; XLA monolithic fusion "
                           "puts both at parity on CPU",
        },
        "sweeps_per_round": sweeps,
        "rows": rows,
        "checks": checks,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return checks, payload


def run(out_rows, results):
    """benchmarks/run.py entry point."""
    rows, sweeps = bench(SIZES)
    checks, payload = write_json(rows, sweeps, SIZES)
    for r in rows:
        out_rows.append((f"wire_ref_n{r['n']}_b{r['bits']}",
                         r["reference_us"], "us/round staged send-side"))
        out_rows.append((f"wire_fused_n{r['n']}_b{r['bits']}",
                         r["fused_us"], f"2-pass, speedup x{r['speedup']}"))
    results["wire_microbench"] = payload
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small size, few reps")
    args = ap.parse_args()
    sizes = TINY_SIZES if args.tiny else SIZES
    rows, sweeps = bench(sizes, reps=3 if args.tiny else REPS)
    checks, _ = write_json(rows, sweeps, sizes, tiny=args.tiny)
    for r in rows:
        print(f"n={r['n']} b={r['bits']}: staged reference "
              f"{r['reference_us']:.0f}us  fused 2-pass {r['fused_us']:.0f}us"
              f"  speedup x{r['speedup']}  (whole-jit: "
              f"{r['whole_jit_reference_us']:.0f} vs "
              f"{r['whole_jit_fused_us']:.0f}us)")
    print(f"sweeps/round: {sweeps} -> {ROOT_JSON}")
    for k, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {k}")
    if not args.tiny and not all(checks.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
