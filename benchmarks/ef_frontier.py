"""Error-feedback frontier: EF-LAQ (top-k sparsify -> sign-magnitude
quantize -> pack, with damped error memory) vs plain dense LAQ at matched
bit-widths, on the paper's logistic-regression substrate.

The dense LAQ grid needs b >= 4 on this problem: at b in {1, 2} the
quantization error of a full-dimension innovation is too coarse for the
criterion's error slack and the loss plateaus orders of magnitude above the
dense floor (b=2) or diverges outright (b=1).  EF-LAQ spends the same bit
budget differently — only the top ``EF_K`` fraction of innovation
coordinates are sent, on a per-upload sign-magnitude grid fitted to the
survivors, and the dropped tail is carried in the worker's error memory
(damped by ``ef_damping``; see docs/compressors.md for why the textbook
undamped carry diverges on an innovation-reference compressor).  Claims
checked, all at matched bit-width:

* **EF-topk reaches the dense-b4 loss target at b=2; plain LAQ b=2 never
  does** (it plateaus ~100x above);
* **the same at b=1**, where plain LAQ diverges;
* **bits-to-target at b=2: EF-topk < plain** (finite vs never);
* **bits-to-target: EF-topk b=2 < plain b=4** — sparsification + error
  memory beats widening the grid as the fix for coarse quantization
  (full horizon only; tiny runs record SKIP);
* structurally, the EF-topk per-upload payload at b=2 is < 1/4 of the
  dense b=2 payload (64 sidecar bits + k(b + ceil(log2 p)) vs 32 + p*b).

Emits ``BENCH_ef.json`` at the repo root (CI bench-smoke runs the
``--tiny`` variant and uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.ef_frontier [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import StrategyConfig, run_gradient_based
from repro.core.quantize import sparse_upload_bits
from repro.core.strategy import static_k

from .common import PAPER_CRITERION, logreg_init, logreg_loss, make_dataset
from .lasg_frontier import first_reach

STEPS = 400
TINY_STEPS = 150          # CI smoke: before the EF runs cross the 1.75x
TINY_TARGET_MULT = 3.0    # target, so tiny gates on a looser multiplier
ALPHA = 2.0
EF_K = 0.025              # top-k keep fraction (2.5% of p=7840 -> k=196)
TARGET_MULT = 1.75        # target = MULT x the dense-b4 floor

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_ef.json"))


def _methods():
    plain = {f"plain_b{b}":
             StrategyConfig(kind="laq", bits=b, criterion=PAPER_CRITERION)
             for b in (4, 2, 1)}
    ef = {f"ef_topk_b{b}":
          StrategyConfig(kind="laq", bits=b, criterion=PAPER_CRITERION,
                         compressor="topk", compressor_k=EF_K,
                         error_feedback=True)
          for b in (2, 1)}
    return {**plain, **ef}


def run(out_rows, results, tiny: bool = False):
    workers, full = make_dataset()
    loss_fn = logreg_loss(full[0].shape[0])
    p = full[0].shape[1] * 10
    steps = TINY_STEPS if tiny else STEPS

    runs = {}
    for name, cfg in _methods().items():
        runs[name] = run_gradient_based(loss_fn, logreg_init(), workers, cfg,
                                        steps=steps, alpha=ALPHA)

    # target relative to the dense fallback the EF pipeline must match: the
    # floor plain LAQ only reaches by widening the grid to b=4
    floor = float(runs["plain_b4"].loss[-1])
    target = (TINY_TARGET_MULT if tiny else TARGET_MULT) * floor

    frontier = {}
    for name, r in runs.items():
        at = first_reach(r, target)
        frontier[name] = dict(
            final_loss=float(r.loss[-1]),
            total_uploads=int(r.cum_uploads[-1]),
            total_bits=float(r.cum_bits[-1]),
            rounds_to_target=None if at is None else at[0],
            bits_to_target=None if at is None else at[1])
        out_rows.append((f"ef_frontier_{name}", float(r.cum_bits[-1]),
                         f"loss={frontier[name]['final_loss']:.4f};"
                         f"to_target={at}"))

    k = static_k(EF_K, p)
    payload = dict(ef_b2=float(sparse_upload_bits(p, k, 2, n_radii=2)),
                   dense_b2=float(32 + 2 * p))

    def bits_to(name):
        v = frontier[name]["bits_to_target"]
        return np.inf if v is None else v

    checks = {
        "EF-topk b=2 reaches the dense-b4 target; plain b=2 plateaus":
            frontier["ef_topk_b2"]["bits_to_target"] is not None
            and frontier["plain_b2"]["bits_to_target"] is None,
        "EF-topk b=1 reaches it; plain b=1 diverges":
            frontier["ef_topk_b1"]["bits_to_target"] is not None
            and frontier["plain_b1"]["bits_to_target"] is None,
        "bits-to-target at b=2: EF-topk < plain":
            bits_to("ef_topk_b2") < bits_to("plain_b2"),
        # the strongest form — EF at 2 bits beats even the dense-b4
        # fallback's bits-to-target.  The margin needs the full horizon, so
        # tiny records None (SKIP) rather than gating on a truncated run.
        "bits-to-target: EF-topk b=2 < plain b=4 (dense fallback)":
            None if tiny else bits_to("ef_topk_b2") < bits_to("plain_b4"),
        "per-upload payload: EF-topk b=2 < 1/4 dense b=2":
            payload["ef_b2"] < 0.25 * payload["dense_b2"],
    }
    results["ef_frontier"] = dict(target_loss=target, dense_floor=floor,
                                  steps=steps, ef_k=EF_K,
                                  per_upload_bits=payload, **frontier)
    results["ef_frontier/claims"] = checks

    with open(ROOT_JSON, "w") as f:
        json.dump({"tiny": tiny, "steps": steps, "target_loss": target,
                   "dense_floor": floor,
                   "rows": [dict(name=n, **row)
                            for n, row in frontier.items()],
                   "checks": checks}, f, indent=1)
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer rounds, looser target")
    args = ap.parse_args()
    out_rows, results = [], {}
    checks = run(out_rows, results, tiny=args.tiny)
    f = results["ef_frontier"]
    print(f"target loss = {f['target_loss']:.4f} "
          f"({TINY_TARGET_MULT if args.tiny else TARGET_MULT}x dense-b4 "
          f"floor {f['dense_floor']:.4f}, steps={f['steps']}, "
          f"k={EF_K:.1%} of p)")
    print(f"{'method':12s} {'final loss':>11s} {'uploads':>8s} "
          f"{'bits':>11s} {'rounds@tgt':>11s} {'bits@tgt':>11s}")
    for name in ("plain_b4", "plain_b2", "plain_b1", "ef_topk_b2",
                 "ef_topk_b1"):
        row = f[name]
        rt, bt = row["rounds_to_target"], row["bits_to_target"]
        print(f"{name:12s} {row['final_loss']:11.5f} "
              f"{row['total_uploads']:8d} {row['total_bits']:11.3e} "
              f"{(str(rt) if rt is not None else 'never'):>11s} "
              f"{(f'{bt:.3e}' if bt is not None else 'never'):>11s}")
    ok = True
    for kk, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {kk}")
        ok &= v is None or bool(v)
    print(f"-> {ROOT_JSON}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
