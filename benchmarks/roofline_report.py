"""Render the dry-run JSON into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        benchmarks/results/dryrun_single_pod.json
"""
from __future__ import annotations

import json
import sys


def fmt_t(sec: float) -> str:
    if sec >= 1.0:
        return f"{sec:.2f}s"
    if sec >= 1e-3:
        return f"{sec*1e3:.1f}ms"
    return f"{sec*1e6:.0f}us"


def render(records, *, title="Roofline (single-pod 16x16, v5e constants)"):
    lines = [f"### {title}", ""]
    lines.append("| arch | shape | t_compute | t_memory | t_collective | "
                 "bottleneck | useful FLOPs | dominant collective |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in records:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                         f"{r.get('error','?')[:60]} | | | | | |")
            continue
        rf = r["roofline"]
        cb = rf.get("coll_breakdown", {})
        dom = max(cb, key=cb.get) if cb and max(cb.values()) > 0 else "-"
        dom_s = f"{dom} ({cb[dom]/1e6:.0f} MB)" if dom != "-" else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(rf['t_compute'])} | "
            f"{fmt_t(rf['t_memory'])} | {fmt_t(rf['t_collective'])} | "
            f"**{rf['bottleneck']}** | {rf['useful_flops_ratio']:.2f} | {dom_s} |")
    return "\n".join(lines)


def render_memory(records):
    lines = ["### Dry-run memory analysis (bytes per device)", ""]
    lines.append("| arch | shape | arguments | outputs | temp | compile s |")
    lines.append("|---|---|---|---|---|---|")
    for r in records:
        if not r.get("ok"):
            continue
        m = r.get("memory", {})
        g = lambda k: f"{m.get(k, 0)/2**30:.2f} GiB" if m else "n/a"
        lines.append(f"| {r['arch']} | {r['shape']} | "
                     f"{g('argument_size_in_bytes')} | {g('output_size_in_bytes')} | "
                     f"{g('temp_size_in_bytes')} | {r.get('compile_s','?')} |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/results/dryrun_single_pod.json"
    with open(path) as f:
        records = json.load(f)
    print(render(records))
    print()
    print(render_memory(records))


if __name__ == "__main__":
    main()
