"""Stochastic lazy-aggregation frontier: SGD / QSGD / SLAQ-7a / SLAQ-WK /
SLAQ-WK2 / SLAQ-PS / SLAQ-VR bits-and-rounds-to-loss (the workload class of
the paper's Table 3, ruled by the LASG criteria of core/lazy_rules.py).

Substrate: the paper's logistic-regression mixture with a deliberately small
minibatch (high gradient variance) — the regime where the deterministic
eq.-7a criterion degenerates: its quantization-error slack inherits the
noise floor, workers skip on noise, the reused stale gradients re-send a
frozen noise realization every round, and the loss plateaus high.  The
headline claims checked:

* SLAQ-WK reaches the dense-baseline loss level in **fewer uploaded bits
  than QSGD** (lazy + innovation quantization beats unbiased per-round
  quantization) ...
* ... and in **fewer communication rounds than SLAQ-7a** at the same batch
  size (7a-on-noise either plateaus above the target or crawls to it);
* SLAQ-PS reaches it in **fewer bits than dense SGD** while skipping most
  rounds (its trigger is noise-free server state);
* SLAQ-WK2 (same-sample rule, second backprop) **skips at least as much as
  SLAQ-WK** at matched thresholds — its criterion is noise-free, WK's only
  variance-corrected;
* SLAQ-VR (svrg-corrected gradients under the plain 7a rule) **reaches the
  deterministic-LAQ loss floor** — which no uncorrected stochastic method
  here does — **in fewer total bits than SLAQ-WK** would need (WK stops at
  its variance floor above the target): variance reduction, not rule
  sharpening, is what removes the stochastic floor.

    PYTHONPATH=src python -m benchmarks.lasg_frontier
"""
from __future__ import annotations

import numpy as np

from repro.core import StrategyConfig, run_gradient_based, run_stochastic

from .common import (PAPER_CRITERION, logreg_init, logreg_loss, make_dataset)

STEPS = 500
BATCH = 10            # of 60 local examples: high minibatch variance
BITS = 3              # paper's stochastic setting
ALPHA = 0.5
SEED = 1
SVRG_PERIOD = 10
DET_TOL = 1.15        # "reaches the deterministic floor": within 15%
METHODS = ("sgd", "qsgd", "slaq", "slaq_wk", "slaq_wk2", "slaq_ps",
           "slaq_vr")
LABELS = {"slaq": "slaq_7a"}    # 7a = LAQ criterion replayed on noise


def first_reach(result, target: float):
    """(rounds, bits) at the first *sustained* crossing: the earliest k with
    ``loss[j] <= target`` for all j >= k.  A plain first-touch would credit
    7a-on-noise for transient noise dips below the target that it
    immediately loses again — exactly the artifact this benchmark measures.
    """
    loss = np.asarray(result.loss)
    trailing_max = np.maximum.accumulate(loss[::-1])[::-1]
    reached = trailing_max <= target
    if not reached.any():
        return None
    k = int(np.argmax(reached))
    return int(result.cum_uploads[k]), float(result.cum_bits[k])


def run(out_rows, results):
    workers, full = make_dataset()
    loss_fn = logreg_loss(full[0].shape[0])
    laq_cfg = StrategyConfig(kind="laq", bits=BITS, criterion=PAPER_CRITERION)
    vr_cfg = laq_cfg._replace(grad_mode="svrg", svrg_period=SVRG_PERIOD)

    # the deterministic-LAQ floor: full local gradients, same quantizer and
    # criterion — the level every *uncorrected* stochastic method plateaus
    # above (the variance floor) and SLAQ-VR is contracted to reach
    det = run_gradient_based(loss_fn, logreg_init(), workers, laq_cfg,
                             steps=STEPS, alpha=ALPHA)
    det_floor = float(det.loss[-1])

    runs = {}
    for kind in METHODS:
        cfg = vr_cfg if kind == "slaq_vr" else laq_cfg
        r = run_stochastic(loss_fn, logreg_init(), workers,
                           "slaq" if kind == "slaq_vr" else kind,
                           steps=STEPS, alpha=ALPHA, batch=BATCH, bits=BITS,
                           seed=SEED, laq_cfg=cfg)
        runs[LABELS.get(kind, kind)] = r

    # target: within 20% of the dense-SGD floor (reachable by every method
    # whose skip decisions track innovation rather than noise)
    target = 1.2 * float(runs["sgd"].loss[-1])
    target_det = DET_TOL * det_floor     # the deterministic-LAQ floor

    frontier = {}
    for name, r in runs.items():
        at = first_reach(r, target)
        at_det = first_reach(r, target_det)
        frontier[name] = dict(
            final_loss=float(r.loss[-1]),
            total_rounds=int(r.cum_uploads[-1]),
            total_bits=float(r.cum_bits[-1]),
            rounds_to_target=None if at is None else at[0],
            bits_to_target=None if at is None else at[1],
            bits_to_det_floor=None if at_det is None else at_det[1])
        out_rows.append((f"lasg_frontier_{name}", float(r.cum_bits[-1]),
                         f"loss={frontier[name]['final_loss']:.4f};"
                         f"to_target={at}"))
    results["lasg_frontier"] = dict(target_loss=target,
                                    det_floor=det_floor,
                                    det_target=target_det, **frontier)

    def to_target(name, field):
        v = frontier[name][field]
        return np.inf if v is None else v

    checks = {
        "bits-to-target: SLAQ-WK < QSGD":
            to_target("slaq_wk", "bits_to_target")
            < to_target("qsgd", "bits_to_target"),
        "rounds-to-target: SLAQ-WK < SLAQ-7a (7a skips on noise)":
            to_target("slaq_wk", "rounds_to_target")
            < to_target("slaq_7a", "rounds_to_target"),
        "bits-to-target: SLAQ-PS < SGD":
            to_target("slaq_ps", "bits_to_target")
            < to_target("sgd", "bits_to_target"),
        "SLAQ-PS skips most rounds":
            frontier["slaq_ps"]["total_rounds"]
            < 0.5 * frontier["sgd"]["total_rounds"],
        "SLAQ-WK final loss beats 7a-on-noise":
            frontier["slaq_wk"]["final_loss"]
            < frontier["slaq_7a"]["final_loss"],
        "SLAQ-WK2 skips at least as much as SLAQ-WK (noise-free rule)":
            frontier["slaq_wk2"]["total_rounds"]
            <= frontier["slaq_wk"]["total_rounds"],
        f"SLAQ-VR reaches the deterministic-LAQ floor (x{DET_TOL})":
            frontier["slaq_vr"]["bits_to_det_floor"] is not None,
        "bits-to-det-floor: SLAQ-VR < SLAQ-WK (VR removes the floor)":
            to_target("slaq_vr", "bits_to_det_floor")
            < to_target("slaq_wk", "bits_to_det_floor"),
    }
    results["lasg_frontier/claims"] = checks
    return checks


def main():
    out_rows, results = [], {}
    checks = run(out_rows, results)
    f = results["lasg_frontier"]
    print(f"target loss = {f['target_loss']:.4f} "
          f"(1.2x dense-SGD floor, batch={BATCH}, b={BITS}); "
          f"det-LAQ floor = {f['det_floor']:.4f} "
          f"(det target x{DET_TOL} = {f['det_target']:.4f})")
    print(f"{'method':9s} {'final loss':>11s} {'rounds':>7s} {'bits':>11s} "
          f"{'rounds@tgt':>11s} {'bits@tgt':>11s} {'bits@det':>11s}")
    for name in ("sgd", "qsgd", "slaq_7a", "slaq_wk", "slaq_wk2", "slaq_ps",
                 "slaq_vr"):
        row = f[name]
        rt, bt = row["rounds_to_target"], row["bits_to_target"]
        bd = row["bits_to_det_floor"]
        print(f"{name:9s} {row['final_loss']:11.5f} {row['total_rounds']:7d} "
              f"{row['total_bits']:11.3e} "
              f"{(str(rt) if rt is not None else 'never'):>11s} "
              f"{(f'{bt:.3e}' if bt is not None else 'never'):>11s} "
              f"{(f'{bd:.3e}' if bd is not None else 'never'):>11s}")
    ok = True
    for k, v in checks.items():
        print(f"[{'PASS' if v else 'FAIL'}] {k}")
        ok &= bool(v)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
