"""Shared benchmark substrate: the paper's two models (regularized logistic
regression; 1-hidden-layer ReLU network) on the synthetic MNIST-like mixture
(the container is offline), M = 10 workers, paper hyperparameters."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CriterionConfig
from repro.data import classification_dataset, split_workers

M_WORKERS = 10
LAMBDA = 0.01
PAPER_CRITERION = CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)


def make_dataset(n_per_class=60, seed=0, heterogeneity=0.0):
    X, Y = classification_dataset(jax.random.PRNGKey(seed), n_per_class=n_per_class)
    Xw, Yw = split_workers(X, Y, M_WORKERS, heterogeneity=heterogeneity)
    return (Xw, Yw), (X, Y)


def logreg_loss(n_total):
    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * LAMBDA * jnp.sum(params["w"] ** 2)) / n_total
    return loss_fn


def logreg_init():
    return {"w": jnp.zeros((10, 784))}


def nn_loss(n_total):
    """784 -> 200 ReLU -> 10, regularized (paper Sec. G)."""
    def loss_fn(params, data):
        x, y = data
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        reg = 0.5 * LAMBDA * (jnp.sum(params["w1"] ** 2) + jnp.sum(params["w2"] ** 2))
        return (ce + reg) / n_total
    return loss_fn


def nn_init(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w1": jax.random.normal(k1, (784, 200)) * (784 ** -0.5),
        "b1": jnp.zeros((200,)),
        "w2": jax.random.normal(k2, (200, 10)) * (200 ** -0.5),
        "b2": jnp.zeros((10,)),
    }


def accuracy_logreg(params, X, Y):
    pred = jnp.argmax(X @ params["w"].T, -1)
    return float(jnp.mean((pred == jnp.argmax(Y, -1)).astype(jnp.float32)))


def accuracy_nn(params, X, Y):
    h = jax.nn.relu(X @ params["w1"] + params["b1"])
    pred = jnp.argmax(h @ params["w2"] + params["b2"], -1)
    return float(jnp.mean((pred == jnp.argmax(Y, -1)).astype(jnp.float32)))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    return out, (time.perf_counter() - t0) * 1e6
