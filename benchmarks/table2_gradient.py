"""Paper Table 2: gradient-based methods (GD / QGD / LAG / LAQ).

Logistic regression runs to a loss-residual threshold (paper: 1e-6 — scaled
here to the synthetic problem); the NN runs a fixed number of iterations.
Reports iterations, communication rounds (uploads), total bits, accuracy.
"""
from __future__ import annotations

import numpy as np

from repro.core import StrategyConfig, run_gradient_based

from .common import (PAPER_CRITERION, accuracy_logreg, accuracy_nn,
                     logreg_init, logreg_loss, make_dataset, nn_init, nn_loss)

BITS_LOGREG = 4      # paper Sec. G: b=4 for logistic regression (gradient tests)
BITS_NN = 8
ALPHA = 2.0          # tuned to the synthetic mixture (paper used 0.02 on MNIST)
STEPS_LOGREG = 800
STEPS_NN = 500
TOL = 1e-6


def _first_below(loss, f_star, tol):
    resid = np.asarray(loss) - f_star
    hit = np.nonzero(resid <= tol)[0]
    return int(hit[0]) + 1 if hit.size else len(loss)


def run(out_rows, results):
    workers, full = make_dataset()
    n_total = full[0].shape[0]

    # ---- logistic regression (strongly convex) ----
    loss_fn = logreg_loss(n_total)
    runs = {}
    for kind in ("gd", "qgd", "lag", "laq"):
        cfg = StrategyConfig(kind=kind, bits=BITS_LOGREG, criterion=PAPER_CRITERION)
        runs[kind] = run_gradient_based(loss_fn, logreg_init(), workers, cfg,
                                        steps=STEPS_LOGREG, alpha=ALPHA)
    f_star = min(float(r.loss[-1]) for r in runs.values())
    for kind, r in runs.items():
        it = _first_below(r.loss, f_star, TOL)
        rounds = int(r.cum_uploads[min(it, len(r.loss)) - 1])
        bits = float(r.cum_bits[min(it, len(r.loss)) - 1])
        acc = accuracy_logreg(r.params, *full)
        results[f"table2/logistic/{kind}"] = dict(
            iterations=it, rounds=rounds, bits=bits, accuracy=acc,
            final_loss=float(r.loss[-1]))
        out_rows.append((f"table2_logistic_{kind}", bits,
                         f"iters={it};rounds={rounds};acc={acc:.4f}"))

    # ---- neural network (nonconvex) ----
    loss_fn = nn_loss(n_total)
    for kind in ("gd", "qgd", "lag", "laq"):
        cfg = StrategyConfig(kind=kind, bits=BITS_NN, criterion=PAPER_CRITERION)
        r = run_gradient_based(loss_fn, nn_init(), workers, cfg,
                               steps=STEPS_NN, alpha=ALPHA)
        acc = accuracy_nn(r.params, *full)
        results[f"table2/nn/{kind}"] = dict(
            iterations=STEPS_NN, rounds=int(r.cum_uploads[-1]),
            bits=float(r.cum_bits[-1]), accuracy=acc,
            final_grad_norm_sq=float(r.grad_norm_sq[-1]))
        out_rows.append((f"table2_nn_{kind}", float(r.cum_bits[-1]),
                         f"rounds={int(r.cum_uploads[-1])};acc={acc:.4f}"))

    # ---- paper-claim checks ----
    t2 = results
    checks = {
        "bits: LAQ < LAG (logistic)":
            t2["table2/logistic/laq"]["bits"] < t2["table2/logistic/lag"]["bits"],
        "bits: LAQ < QGD < GD (logistic)":
            t2["table2/logistic/laq"]["bits"] < t2["table2/logistic/qgd"]["bits"]
            < t2["table2/logistic/gd"]["bits"],
        "rounds: LAQ << QGD (logistic)":
            t2["table2/logistic/laq"]["rounds"] < 0.5 * t2["table2/logistic/qgd"]["rounds"],
        "accuracy parity (logistic)":
            abs(t2["table2/logistic/laq"]["accuracy"]
                - t2["table2/logistic/gd"]["accuracy"]) < 0.02,
        "bits: LAQ lowest (nn)":
            t2["table2/nn/laq"]["bits"] == min(t2[f"table2/nn/{k}"]["bits"]
                                               for k in ("gd", "qgd", "lag", "laq")),
    }
    results["table2/claims"] = checks
    return checks
