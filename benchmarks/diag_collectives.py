"""Diagnostic: per-shape collective inventory of one (arch x shape) probe —
aggregates every collective op in the L=1 unrolled HLO by (kind, shape) so a
hillclimb iteration can see exactly *which* tensor dominates the collective
term rather than guessing.

    PYTHONPATH=src python -m benchmarks.diag_collectives qwen3-moe-30b-a3b train_4k [overrides]
"""
from __future__ import annotations

import dataclasses
import re
import sys

from repro.launch.dryrun import _build_lowered  # sets XLA_FLAGS on import
from repro.configs import for_shape, get_config
from repro.core.strategy import StrategyConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import _DTYPE_BYTES, _SHAPE_RE
from repro.models.config import INPUT_SHAPES
from repro.optim import sgd

_OP = re.compile(r"%(\S+?)\.?\d* = (\S+) (all-gather|all-reduce|reduce-scatter"
                 r"|all-to-all|collective-permute)\(")


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    overrides = {}
    for kv in sys.argv[3:]:
        k, v = kv.split("=")
        overrides[k] = eval(v)  # noqa: S307 — operator tool
    shape = INPUT_SHAPES[shape_name]
    cfg = for_shape(get_config(arch), shape)
    cfg = dataclasses.replace(cfg, n_layers=1, scan_layers=False, **overrides)
    mesh = make_production_mesh()
    strategy = StrategyConfig(kind="laq", bits=4, per_leaf_radius=True)
    lowered = _build_lowered(cfg, shape, mesh, strategy, sgd(), "float",
                             False, False)
    hlo = lowered.compile().as_text()

    totals = {}
    for m in _OP.finditer(hlo):
        shape_str, kind = m.group(2), m.group(3)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            if dt in _DTYPE_BYTES:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
        key = (kind, shape_str.split("{")[0])
        c, b = totals.get(key, (0, 0))
        totals[key] = (c + 1, b + nbytes)

    print(f"# {arch} x {shape_name} L=1 unrolled, overrides={overrides}")
    for (kind, shp), (count, nbytes) in sorted(totals.items(),
                                               key=lambda kv: -kv[1][1])[:25]:
        print(f"{nbytes/2**20:10.1f} MiB  x{count:3d}  {kind:20s} {shp}")


if __name__ == "__main__":
    main()
