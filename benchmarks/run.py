"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (for table rows ``us_per_call`` holds
the headline numeric, usually total wire bits) and writes the full structured
results + claim checks to benchmarks/results/paper_repro.json.
"""
from __future__ import annotations

import json
import os
import sys
import time


def main() -> None:
    t0 = time.time()
    out_rows, results = [], {}
    all_checks = {}

    from . import (adaptive_sweep, bits_sweep, convergence, lasg_frontier,
                   participation_frontier, table2_gradient, table3_stochastic,
                   wire_microbench)
    for name, mod in (("table2", table2_gradient), ("table3", table3_stochastic),
                      ("convergence", convergence), ("bits_sweep", bits_sweep),
                      ("adaptive_sweep", adaptive_sweep),
                      ("lasg_frontier", lasg_frontier),
                      ("participation_frontier", participation_frontier),
                      ("wire_microbench", wire_microbench)):
        t = time.time()
        checks = mod.run(out_rows, results)
        all_checks.update({f"{name}: {k}": v for k, v in checks.items()})
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, val, derived in out_rows:
        print(f"{name},{val},{derived}")

    os.makedirs(os.path.join(os.path.dirname(__file__), "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "results", "paper_repro.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)

    print("\n# paper-claim validation", file=sys.stderr)
    failed = 0
    for k, v in all_checks.items():
        print(f"#  [{'PASS' if v else 'FAIL'}] {k}", file=sys.stderr)
        failed += (not v)
    print(f"# {len(all_checks)-failed}/{len(all_checks)} claims hold "
          f"({time.time()-t0:.1f}s total) -> {path}", file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
