"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (for table rows ``us_per_call`` holds
the headline numeric, usually total wire bits) and writes the full structured
results + claim checks to benchmarks/results/paper_repro.json.

Flags:

* ``--claims-only`` — run only the modules that gate paper claims (skips the
  timing-only microbenchmarks, whose numbers are machine noise on CI).
* ``--tiny`` — forward ``tiny=True`` to every module whose ``run`` accepts
  it (shorter horizons / looser targets for CI smoke), and register the
  ``wire_roofline`` pass: compiled cost analysis of the fused wire pipeline
  (launch/roofline.py) with no timing, so it gates even on noisy runners.

Any module that *raises* fails the harness exactly like a failed claim: the
exception is recorded as a synthetic failing check and the exit code is
nonzero — a crashed benchmark must never read as green.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback


def _modules(claims_only: bool, tiny: bool = False):
    import types

    from . import (adaptive_sweep, bits_sweep, convergence, ef_frontier,
                   fault_frontier, lasg_frontier, lm_frontier,
                   participation_frontier, serve_frontier, table2_gradient,
                   table3_stochastic, wire_microbench)
    mods = [("table2", table2_gradient), ("table3", table3_stochastic),
            ("convergence", convergence), ("bits_sweep", bits_sweep),
            ("adaptive_sweep", adaptive_sweep),
            ("lasg_frontier", lasg_frontier),
            ("participation_frontier", participation_frontier),
            ("ef_frontier", ef_frontier),
            ("fault_frontier", fault_frontier),
            ("lm_frontier", lm_frontier),
            ("serve_frontier", serve_frontier),
            ("wire_microbench", wire_microbench)]
    if claims_only:
        # timing-only modules: their checks are perf trajectories, not
        # paper claims, and CI runners are too noisy to gate on them
        mods = [(n, m) for n, m in mods if n != "wire_microbench"]
    if tiny:
        # roofline-only pass (compiled cost analysis, no timing): it is
        # deterministic, so it can gate CI smoke even when the timing
        # microbenchmark above is skipped
        mods.append(("wire_roofline", types.SimpleNamespace(
            run=wire_microbench.run_roofline)))
    return mods


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--claims-only", action="store_true",
                    help="only modules that gate paper claims")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: forward tiny=True where supported")
    args = ap.parse_args(argv)

    t0 = time.time()
    out_rows, results = [], {}
    all_checks = {}

    for name, mod in _modules(args.claims_only, args.tiny):
        t = time.time()
        kwargs = {}
        if args.tiny and "tiny" in inspect.signature(mod.run).parameters:
            kwargs["tiny"] = True
        try:
            checks = mod.run(out_rows, results, **kwargs)
        except Exception:
            traceback.print_exc()
            checks = {"raised no exception": False}
        all_checks.update({f"{name}: {k}": v for k, v in checks.items()})
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, val, derived in out_rows:
        print(f"{name},{val},{derived}")

    os.makedirs(os.path.join(os.path.dirname(__file__), "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "results", "paper_repro.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)

    print("\n# paper-claim validation", file=sys.stderr)
    failed = skipped = 0
    for k, v in all_checks.items():
        tag = "SKIP" if v is None else "PASS" if v else "FAIL"
        print(f"#  [{tag}] {k}", file=sys.stderr)
        failed += v is not None and not v
        skipped += v is None
    print(f"# {len(all_checks)-failed-skipped}/{len(all_checks)} claims hold "
          f"({skipped} skipped, {time.time()-t0:.1f}s total) -> {path}",
          file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
