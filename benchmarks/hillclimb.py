"""Perf hillclimb driver: hypothesis -> change -> re-lower -> validate, for
the three selected (arch x shape) pairs.  Each experiment re-runs the dry-run
roofline probe with one (or a stack of) config/strategy overrides and records
before/after terms into benchmarks/results/hillclimb.json.

Run in a fresh process (needs the 512-device XLA flag set by repro.launch.dryrun
import, so invoke as a module):

    PYTHONPATH=src python -m benchmarks.hillclimb --pair moe
"""
from __future__ import annotations

import argparse
import json
import os

from repro.launch.dryrun import run_one  # sets XLA_FLAGS on import


_OUT = "benchmarks/results/hillclimb.json"


def _exp(results, name, **kw):
    if any(r.get("tag") == name for r in results):
        print(f"[hillclimb] {name}: cached, skipping")
        return None
    try:
        rec = run_one(tag=name, **kw)
    except Exception as e:  # noqa: BLE001
        import traceback
        traceback.print_exc()
        rec = {"tag": name, "ok": False, "error": f"{type(e).__name__}: {e}", **{
            k: kw.get(k) for k in ("arch", "shape_name")}}
    results.append(rec)
    with open(_OUT, "w") as f:          # incremental: survive compiler crashes
        json.dump(results, f, indent=1)
    return rec


def moe_pair(results):
    """qwen3-moe-30b-a3b x train_4k — most collective-bound (baseline:
    t_coll 16.2s > t_mem 12.6s; dominant all-reduce 551 GB/device)."""
    A = dict(arch="qwen3-moe-30b-a3b", shape_name="train_4k")
    # iter 1: scatter-add combine. Hypothesis: the gather combine makes GSPMD
    # all-gather the expert buffer (E*C*D bf16 = 128*320*2048*2B = 168MB/layer
    # /group *48L -> hundreds of GB); scatter-add lowers to local partial
    # scatter + all-reduce of T*D only (65536*2048*2B = 268MB/layer) => ~10x
    # less MoE combine traffic.
    _exp(results, "moe+scatter", **A, cfg_overrides={"moe_combine": "scatter"})
    # iter 2: + LAQ state in bf16. Hypothesis: qhat/server_agg are f32 copies
    # of a 30B-param pytree (7.5 GB each per device /16 model shards); bf16
    # halves the LAQ state bytes -> memory term down by ~2 GB reads/writes.
    _exp(results, "moe+scatter+bf16state", **A,
         cfg_overrides={"moe_combine": "scatter"},
         strategy_overrides={"state_bf16": True})
    # iter 3: + capacity_factor 1.0. Hypothesis: expert compute, dispatch
    # gather and combine payloads all scale with C => 20% off the MoE terms.
    _exp(results, "moe+scatter+bf16state+cf1.0", **A,
         cfg_overrides={"moe_combine": "scatter", "capacity_factor": 1.0},
         strategy_overrides={"state_bf16": True})


def musicgen_pair(results):
    """musicgen-medium x train_4k — worst useful-FLOPs fraction (0.14) and
    24 attention heads not divisible by the 16-way model axis (attention
    replicated; only d_ff=6144 tensor-parallel)."""
    A = dict(arch="musicgen-medium", shape_name="train_4k")
    # iter 1: microbatch=4. Hypothesis: memory term is dominated by saved
    # layer activations + attention transients of the 16-per-device batch;
    # 4 sequential microbatches cut live activation bytes ~4x at the cost of
    # 3 extra grad-accumulator passes over p (p is tiny for 1.5B/16 shards).
    _exp(results, "musicgen+mb4", **A, microbatch=4)
    # iter 2: + bf16 LAQ state (same rationale as MoE pair).
    _exp(results, "musicgen+mb4+bf16state", **A, microbatch=4,
         strategy_overrides={"state_bf16": True})
    # iter 3: batch-sharded attention. Hypothesis: 24 heads % 16 != 0 leaves
    # attention replicated, so every device computes full-local-batch (16)
    # attention: f32 score blocks [16,24,1024,512] ~ 800MB x ~16 block pairs
    # x 48 layers x ~3 passes dominate the memory term. Resharding the local
    # batch over the 16-way model axis divides those transients by 16 at the
    # cost of a [B,S,D] reshard in+out per layer (~0.5 GB vs ~12 GB saved).
    _exp(results, "musicgen+mb4+bf16state+batchattn", **A, microbatch=4,
         strategy_overrides={"state_bf16": True},
         cfg_overrides={"attn_batch_shard": True})


def qwen_pair(results):
    """qwen3-8b x train_4k — most representative of the paper's technique:
    the LAQ wire itself on a large dense LM."""
    A = dict(arch="qwen3-8b", shape_name="train_4k")
    # paper-faithful strategy baselines for comparison: GD (dense) vs LAQ
    _exp(results, "qwen+gd-baseline", **A, strategy_kind="gd")
    # iter 1: microbatch=8 on the memory term (B_loc=16 x 4k x 4k saved
    # activations ~19GB -> ~2.4GB + grad accumulator 2GB).
    _exp(results, "qwen+mb8", **A, microbatch=8)
    # iter 2: + bf16 LAQ state (qhat + server_agg: 2x2GB -> 2x1GB /device).
    _exp(results, "qwen+mb8+bf16state", **A, microbatch=8,
         strategy_overrides={"state_bf16": True})
    # iter 3 (beyond-paper, multi-pod): hierarchical pod-level LAQ with the
    # packed uint8 wire. Hypothesis: the pod-crossing gradient exchange drops
    # from an 8p-byte float psum to a (b/8)p all_gather (b=4 => 16x fewer DCN
    # bytes); intra-pod stays full-precision psum. (microbatch=1: the 512-dev
    # unrolled-probe compile of the mb8 variant exhausts host RAM.)
    _exp(results, "qwen+pod-float", **A, multi_pod=True, hierarchical=True,
         wire="float", strategy_overrides={"state_bf16": True})
    _exp(results, "qwen+pod-packed", **A, multi_pod=True, hierarchical=True,
         wire="packed", strategy_overrides={"state_bf16": True})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all", choices=["moe", "musicgen",
                                                      "qwen", "all"])
    ap.add_argument("--out", default="benchmarks/results/hillclimb.json")
    args = ap.parse_args()
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    if args.pair in ("moe", "all"):
        moe_pair(results)
    if args.pair in ("musicgen", "all"):
        musicgen_pair(results)
    if args.pair in ("qwen", "all"):
        qwen_pair(results)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"-> {args.out} ({len(results)} records)")


if __name__ == "__main__":
    main()
