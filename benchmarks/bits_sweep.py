"""Paper supp: communication cost vs quantization bits b, plus the Pallas
wire-kernel microbenchmark (us_per_call on this host; interpret mode on CPU —
the number is a correctness-path latency, the TPU claim is structural)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import StrategyConfig, run_gradient_based
from repro.kernels import dequant_acc, quantize_pack

from .common import PAPER_CRITERION, logreg_init, logreg_loss, make_dataset, timed


def run(out_rows, results):
    workers, full = make_dataset()
    loss_fn = logreg_loss(full[0].shape[0])

    # ---- bits sweep (paper supp: b in {2,4,8}) ----
    sweep = {}
    for b in (2, 4, 8):
        r = run_gradient_based(loss_fn, logreg_init(), workers,
                               StrategyConfig(kind="laq", bits=b,
                                              criterion=PAPER_CRITERION),
                               steps=400, alpha=2.0)
        sweep[b] = dict(bits=float(r.cum_bits[-1]),
                        rounds=int(r.cum_uploads[-1]),
                        final_loss=float(r.loss[-1]))
        out_rows.append((f"bits_sweep_b{b}", float(r.cum_bits[-1]),
                         f"rounds={sweep[b]['rounds']};loss={sweep[b]['final_loss']:.2e}"))
    results["bits_sweep"] = sweep

    # ---- wire kernel micro-bench ----
    n = 1 << 20
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    qh = jnp.zeros((n,))
    R = jnp.max(jnp.abs(g))
    for bits in (4, 8):
        quantize_pack(g, qh, R, bits)  # compile
        _, us = timed(lambda: jax.block_until_ready(quantize_pack(g, qh, R, bits)))
        out_rows.append((f"kernel_quantize_pack_b{bits}_n1M", us, "interpret-mode us"))
        pk, _ = quantize_pack(g, qh, R, bits)
        pks = jnp.stack([pk] * 4)
        Rs, keep = jnp.full((4,), R), jnp.ones((4,))
        dequant_acc(pks, Rs, keep, bits, n)
        _, us = timed(lambda: jax.block_until_ready(dequant_acc(pks, Rs, keep, bits, n)))
        out_rows.append((f"kernel_dequant_acc_b{bits}_W4_n1M", us, "interpret-mode us"))

    checks = {"fewer bits per round with smaller b":
              sweep[2]["bits"] < sweep[4]["bits"] < sweep[8]["bits"]}
    results["bits_sweep/claims"] = checks
    return checks
