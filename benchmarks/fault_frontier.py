"""Fault frontier: LAQ under corrupt, crashed, and diverging workers.

Headline benchmark for the fault subsystem (core/faults.py injection +
core/defense.py tolerance).  One small multinomial-logistic problem
(W=6, p=32, with an L2 term so the optimum is interior — on separable
data a crash-ghost's stale qhat grows the margin for free and the
"damage" would show up as *lower* loss), one loss target = 1.02x the
fault-free final loss, and a grid of fault x defense cells:

* **clean / clean_defended** — defense at fault rate 0 is bitwise free:
  identical loss trace, identical bits (the overhead claim is exact
  equality, not a tolerance);
* **inf corruption (10% of payloads)** — undefended the aggregate goes
  non-finite and the run never reaches target; upload validation rejects
  the non-finite payloads (they still pay their bits — rejection is a
  server decision, the transmission happened) and reaches target within
  1.5x the clean bits-to-target;
* **nan corruption** — the sneaky one: a NaN gradient zeroes its own
  innovation (R = max|g - qhat| = NaN makes the R>0 grid guard drop the
  payload) so the run *stays finite*, but err_sq = NaN poisons the
  worker's eps-hat ledger and forces dense uploads until the next
  committed upload overwrites it.  Undefended pays a silent >=10% upload
  tax; validation (which finite-checks err_sq, not just the payload)
  keeps the ledger clean;
* **crash-restart (2%/round)** — a restarted worker loses its CommState
  replica; naively re-bootstrapping leaves the server holding the dead
  replica's stale qhat as a permanent ghost bias (final loss >= 1.3x
  clean), while reconciliation (subtract the stale qhat from the server
  aggregate at restart) lands on the clean floor;
* **byzantine scaling (dense QGD)** — a -40x scaled payload is finite
  and well-shaped, so validation alone cannot see it.  Coordinate-wise
  trimmed-mean bounds the damage (>=10x lower final loss than plain
  sum); note robust aggregators break the LAQ recursion invariant
  (worker commits its full delta to qhat, server commits the trimmed
  version), so on the *lazy* path the right tool is the norm-gate,
  which rejects outliers against a per-worker accepted-norm EMA and
  actually reaches target (docs/robustness.md, "recursion drift");
* **divergence watchdog** — chunked run with checkpoint/rollback
  (core/defense.py run_with_watchdog): on the inf-corrupted run it
  detects the explosion, rolls back, escalates to a validating engine
  (deterministic fault streams replay identically, so a plain retry
  would hit the same fault), and still converges.

Emits ``BENCH_faults.json`` at the repo root (CI bench-smoke runs the
``--tiny`` variant and uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.fault_frontier [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CriterionConfig, DefenseConfig, FaultConfig,
                        RoundEngine, StrategyConfig, WatchdogConfig,
                        run_gradient_based, run_with_watchdog)
from repro.core.engine import FullBatchSource
from repro.data import classification_dataset, split_workers

STEPS = 120
TINY_STEPS = 60           # CI smoke: convergence claims only — the margin
                          # claims (bits ratio, crash drift) need the full
                          # horizon and record SKIP
W = 6
ALPHA = 0.05
BITS = 4
L2 = 1e-2                 # interior optimum: see module docstring
CRIT = CriterionConfig(D=10, xi=0.001, t_bar=6)
TARGET_MULT = 1.02        # target = MULT x fault-free final loss
BITS_RATIO_MAX = 1.5      # defended bits-to-target vs clean (measured 1.18)
CRASH_DRIFT_MIN = 1.3     # naive-crash final vs clean final (measured 1.60)
TRIM_GAIN_MIN = 10.0      # sum final vs trimmed final (measured ~316x)

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_faults.json"))


def _problem():
    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=30,
                                  n_classes=4, n_features=8, separation=2.0,
                                  noise=1.0)
    data = split_workers(X, Y, W)

    def loss_fn(params, shard):
        Xs, Ys = shard
        ce = -jnp.mean(jnp.sum(Ys * jax.nn.log_softmax(Xs @ params),
                               axis=-1))
        return ce + L2 * jnp.sum(params * params)

    return loss_fn, jnp.zeros((8, 4)), data


def _bits_to(res, target):
    loss = np.asarray(res.loss)
    hit = np.nonzero(loss <= target)[0]
    return None if hit.size == 0 else float(np.asarray(res.cum_bits)[hit[0]])


INF = FaultConfig(corrupt_p=0.1, corrupt_kind="inf")
NAN = FaultConfig(corrupt_p=0.1, corrupt_kind="nan")
CRASH = FaultConfig(crash_p=0.02)
SCALE = FaultConfig(corrupt_p=0.08, corrupt_kind="scale", corrupt_scale=-40.0)
VALIDATE = DefenseConfig(validate=True)


def _cells():
    laq = StrategyConfig(kind="laq", bits=BITS, criterion=CRIT)
    qgd = laq._replace(kind="qgd")
    return {
        "clean": laq,
        "clean_defended": laq._replace(
            defense=DefenseConfig(validate=True, gate_mult=6.0)),
        "inf_undefended": laq._replace(faults=INF),
        "inf_defended": laq._replace(faults=INF, defense=VALIDATE),
        "nan_undefended": laq._replace(faults=NAN),
        "nan_defended": laq._replace(faults=NAN, defense=VALIDATE),
        "crash_naive": laq._replace(
            faults=CRASH, defense=DefenseConfig(reconcile_crashes=False)),
        "crash_reconciled": laq._replace(faults=CRASH),
        "scale_qgd_sum": qgd._replace(faults=SCALE),
        "scale_qgd_trimmed": qgd._replace(faults=SCALE,
                                          aggregator="trimmed_mean",
                                          trim_frac=0.34),
        "scale_laq_gated": laq._replace(
            faults=SCALE, defense=DefenseConfig(validate=True, gate_mult=4.0)),
    }


def _watchdog_row(loss_fn, p0, data, steps):
    """Undefended inf corruption under the watchdog: rollback + escalate."""
    src = FullBatchSource(loss_fn, data)
    cfg = StrategyConfig(kind="laq", bits=BITS, criterion=CRIT, faults=INF)

    def escalate(engine):
        return RoundEngine(src, engine.cfg._replace(defense=VALIDATE),
                           alpha=ALPHA)

    with tempfile.TemporaryDirectory() as td:
        res, log, _ = run_with_watchdog(
            RoundEngine(src, cfg, alpha=ALPHA), p0, steps,
            ckpt_path=os.path.join(td, "wd.npz"),
            wd=WatchdogConfig(chunk=20, explode_mult=25.0), escalate=escalate)
    return res, log


def run(out_rows, results, tiny: bool = False):
    loss_fn, p0, data = _problem()
    steps = TINY_STEPS if tiny else STEPS

    runs = {name: run_gradient_based(loss_fn, p0, data, cfg, steps=steps,
                                     alpha=ALPHA)
            for name, cfg in _cells().items()}
    wd_res, wd_log = _watchdog_row(loss_fn, p0, data, steps)
    runs["watchdog_inf"] = wd_res

    clean_final = float(runs["clean"].loss[-1])
    target = TARGET_MULT * clean_final

    frontier = {}
    for name, r in runs.items():
        loss = np.asarray(r.loss)
        bt = _bits_to(r, target)
        frontier[name] = dict(
            final_loss=float(loss[-1]),
            finite=bool(np.isfinite(loss).all()),
            total_uploads=int(r.cum_uploads[-1]),
            total_bits=float(r.cum_bits[-1]),
            bits_to_target=bt)
        out_rows.append((f"fault_frontier_{name}", float(r.cum_bits[-1]),
                         f"loss={frontier[name]['final_loss']:.4f};"
                         f"to_target={bt}"))

    def f(name, key="final_loss"):
        return frontier[name][key]

    def bits_to(name):
        v = frontier[name]["bits_to_target"]
        return np.inf if v is None else v

    full = None if tiny else True  # margin claims SKIP on the tiny horizon
    checks = {
        "defense at fault rate 0 is free: bitwise-identical loss, equal bits":
            bool(np.array_equal(np.asarray(runs["clean"].loss),
                                np.asarray(runs["clean_defended"].loss)))
            and f("clean", "total_bits") == f("clean_defended", "total_bits"),
        "inf corruption: undefended goes non-finite and never reaches target":
            (not f("inf_undefended", "finite"))
            and frontier["inf_undefended"]["bits_to_target"] is None,
        "inf corruption: validation reaches target":
            frontier["inf_defended"]["bits_to_target"] is not None
            and f("inf_defended", "finite"),
        f"inf corruption: defended bits-to-target <= {BITS_RATIO_MAX}x clean":
            full and bits_to("inf_defended")
            <= BITS_RATIO_MAX * bits_to("clean"),
        "nan corruption: undefended stays finite but pays >=10% upload tax":
            f("nan_undefended", "finite")
            and f("nan_undefended", "total_uploads")
            >= 1.10 * f("clean", "total_uploads"),
        "nan corruption: err_sq validation reaches target, uploads <= "
        "undefended":
            frontier["nan_defended"]["bits_to_target"] is not None
            and f("nan_defended", "total_uploads")
            <= f("nan_undefended", "total_uploads"),
        f"crash: naive restart's ghost bias >= {CRASH_DRIFT_MIN}x clean "
        "final loss":
            full and f("crash_naive") >= CRASH_DRIFT_MIN * clean_final,
        "crash: reconciled restart lands on the clean floor (<=1.05x)":
            f("crash_reconciled") <= 1.05 * clean_final,
        f"byzantine scale: trimmed-mean final >= {TRIM_GAIN_MIN:.0f}x lower "
        "than sum":
            f("scale_qgd_sum")
            >= TRIM_GAIN_MIN * f("scale_qgd_trimmed"),
        "byzantine scale on the lazy path: norm-gate reaches target":
            frontier["scale_laq_gated"]["bits_to_target"] is not None,
        "watchdog: rolls back (>=1), escalates, converges":
            len(wd_log["rollbacks"]) >= 1 and not wd_log["gave_up"]
            and frontier["watchdog_inf"]["bits_to_target"] is not None,
    }

    results["fault_frontier"] = dict(
        target_loss=target, clean_final=clean_final, steps=steps,
        watchdog_log=dict(rollbacks=len(wd_log["rollbacks"]),
                          wasted_rounds=int(wd_log["wasted_rounds"]),
                          wasted_bits=float(wd_log["wasted_bits"]),
                          gave_up=bool(wd_log["gave_up"])),
        **frontier)
    results["fault_frontier/claims"] = checks

    with open(ROOT_JSON, "w") as fh:
        json.dump({"tiny": tiny, "steps": steps, "target_loss": target,
                   "clean_final": clean_final,
                   "watchdog_log": results["fault_frontier"]["watchdog_log"],
                   "rows": [dict(name=n, **row)
                            for n, row in frontier.items()],
                   "checks": checks}, fh, indent=1)
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer rounds, margin claims skipped")
    args = ap.parse_args()
    out_rows, results = [], {}
    checks = run(out_rows, results, tiny=args.tiny)
    fr = results["fault_frontier"]
    print(f"target loss = {fr['target_loss']:.4f} "
          f"({TARGET_MULT}x clean final {fr['clean_final']:.4f}, "
          f"steps={fr['steps']})")
    print(f"{'cell':18s} {'final loss':>11s} {'finite':>6s} {'uploads':>8s} "
          f"{'bits':>11s} {'bits@tgt':>11s}")
    for name in ("clean", "clean_defended", "inf_undefended", "inf_defended",
                 "nan_undefended", "nan_defended", "crash_naive",
                 "crash_reconciled", "scale_qgd_sum", "scale_qgd_trimmed",
                 "scale_laq_gated", "watchdog_inf"):
        row = fr[name]
        bt = row["bits_to_target"]
        print(f"{name:18s} {row['final_loss']:11.5f} "
              f"{str(row['finite']):>6s} {row['total_uploads']:8d} "
              f"{row['total_bits']:11.3e} "
              f"{(f'{bt:.3e}' if bt is not None else 'never'):>11s}")
    print(f"watchdog: {fr['watchdog_log']}")
    ok = True
    for k, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {k}")
        ok &= v is None or bool(v)
    print(f"-> {ROOT_JSON}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
