"""Paper Table 3: minibatch stochastic methods (SGD / QSGD / SSGD / SLAQ)."""
from __future__ import annotations

from repro.core import CriterionConfig, StrategyConfig, run_stochastic

from .common import (accuracy_logreg, accuracy_nn, logreg_init, logreg_loss,
                     make_dataset, nn_init, nn_loss)

BITS = 3              # paper: b=3 for logistic regression (stochastic tests)
BITS_NN = 8
ALPHA = 0.5
BATCH = 50            # paper: 500 of 60k ~ same local fraction
STEPS = 400
STEPS_NN = 300
CRIT = CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)


def run(out_rows, results):
    workers, full = make_dataset()
    n_total = full[0].shape[0]

    for model, loss_fac, init_fn, acc_fn, steps, bits in (
            ("logistic", logreg_loss, logreg_init, accuracy_logreg, STEPS, BITS),
            ("nn", nn_loss, nn_init, accuracy_nn, STEPS_NN, BITS_NN)):
        loss_fn = loss_fac(n_total)
        for kind in ("sgd", "qsgd", "ssgd", "slaq"):
            r = run_stochastic(loss_fn, init_fn(), workers, kind,
                               steps=steps, alpha=ALPHA, batch=BATCH, bits=bits,
                               density=0.1,
                               laq_cfg=StrategyConfig(kind="laq", bits=bits,
                                                      criterion=CRIT))
            acc = acc_fn(r.params, *full)
            results[f"table3/{model}/{kind}"] = dict(
                iterations=steps, rounds=int(r.cum_uploads[-1]),
                bits=float(r.cum_bits[-1]), accuracy=acc,
                final_loss=float(r.loss[-1]))
            out_rows.append((f"table3_{model}_{kind}", float(r.cum_bits[-1]),
                             f"rounds={int(r.cum_uploads[-1])};acc={acc:.4f}"))

    t3 = results
    checks = {
        "bits: SLAQ < QSGD (logistic)":
            t3["table3/logistic/slaq"]["bits"] < t3["table3/logistic/qsgd"]["bits"],
        "bits: SLAQ < SSGD (logistic)":
            t3["table3/logistic/slaq"]["bits"] < t3["table3/logistic/ssgd"]["bits"],
        "rounds: SLAQ <= SGD (logistic)":
            t3["table3/logistic/slaq"]["rounds"] <= t3["table3/logistic/sgd"]["rounds"],
        "accuracy parity (logistic)":
            abs(t3["table3/logistic/slaq"]["accuracy"]
                - t3["table3/logistic/sgd"]["accuracy"]) < 0.03,
    }
    results["table3/claims"] = checks
    return checks
