"""Paper Figs. 3-5: convergence curves (loss residual, gradient norm,
quantization-error radius decay) + the heterogeneity study of the supp."""
from __future__ import annotations

import numpy as np

from repro.core import StrategyConfig, run_gradient_based

from .common import (PAPER_CRITERION, logreg_init, logreg_loss, make_dataset)


def run(out_rows, results):
    workers, full = make_dataset()
    n_total = full[0].shape[0]
    loss_fn = logreg_loss(n_total)

    curves = {}
    for kind in ("gd", "qgd", "lag", "laq"):
        r = run_gradient_based(loss_fn, logreg_init(), workers,
                               StrategyConfig(kind=kind, bits=4,
                                              criterion=PAPER_CRITERION),
                               steps=600, alpha=2.0)
        curves[kind] = r
    f_star = min(float(r.loss[-1]) for r in curves.values())

    for kind, r in curves.items():
        resid = np.maximum(np.asarray(r.loss) - f_star, 1e-14)
        # linear-rate fit on log residual (paper Fig. 4a / Theorem 1)
        seg = np.log(resid[20:400])
        slope = float(np.polyfit(np.arange(seg.size), seg, 1)[0])
        results[f"convergence/{kind}"] = dict(
            rate_log_slope=slope,
            loss_curve=np.asarray(r.loss)[::20].tolist(),
            grad_norm_curve=np.asarray(r.grad_norm_sq)[::20].tolist(),
            bits_curve=np.asarray(r.cum_bits)[::20].tolist(),
            rounds_curve=np.asarray(r.cum_uploads)[::20].tolist(),
            quant_radius_curve=np.asarray(r.quant_err)[::20].tolist(),
        )
        out_rows.append((f"convergence_{kind}", slope, "log-residual slope"))

    # quantization error decays linearly alongside (Fig. 3 / Thm 1 19b)
    qe = np.asarray(curves["laq"].quant_err)
    early, late = float(np.mean(qe[5:50])), float(np.mean(qe[-50:]))
    results["convergence/quant_error_decay"] = dict(early=early, late=late,
                                                    ratio=late / max(early, 1e-12))

    # heterogeneity study (supp): non-iid shards -> LAQ still converges
    workers_het, full_het = make_dataset(heterogeneity=0.8, seed=1)
    r = run_gradient_based(logreg_loss(full_het[0].shape[0]), logreg_init(),
                           workers_het,
                           StrategyConfig(kind="laq", bits=4,
                                          criterion=PAPER_CRITERION),
                           steps=400, alpha=2.0)
    results["convergence/heterogeneous_laq"] = dict(
        final_loss=float(r.loss[-1]), rounds=int(r.cum_uploads[-1]),
        bits=float(r.cum_bits[-1]))
    out_rows.append(("convergence_het_laq", float(r.loss[-1]),
                     f"rounds={int(r.cum_uploads[-1])}"))

    checks = {
        "LAQ linear rate (slope<0)": results["convergence/laq"]["rate_log_slope"] < -0.005,
        "LAQ ~ GD rate (within 2x)":
            results["convergence/laq"]["rate_log_slope"]
            < 0.5 * results["convergence/gd"]["rate_log_slope"],
        "quant error decays 20x+":
            results["convergence/quant_error_decay"]["ratio"] < 0.05,
        "heterogeneous LAQ converges":
            results["convergence/heterogeneous_laq"]["final_loss"] < 1.0,
    }
    results["convergence/claims"] = checks
    return checks
