"""Fixed-vs-adaptive bit-width frontier (A-LAQ) on a synthetic regression.

Distributed ridge regression  f_m(w) = ||X_m w - y_m||^2 / (2N) + lam/2 ||w||^2
over M = 10 workers — strongly convex, so LAQ converges linearly and the
innovation radius decays (paper Fig. 3), which is exactly the slack the
adaptive schedules harvest: high width while R is large, low width once it
has decayed.

Headline claim checked: the radius-decay schedule reaches the fixed-4-bit
final loss with fewer cumulative wire bits; the budgeted controller respects
its pro-rata allowance while staying near that frontier.

Both adaptive schedules run with **scale-free** thresholds
(``threshold_mode="rel"``, core/adaptive.py): the fractions below are of the
bootstrap-round anchor radius, not of this problem's absolute radius scale —
the same tuple works unchanged on any workload (the earlier absolute tuple
had to be re-derived from each problem's R trajectory).

    PYTHONPATH=src python -m benchmarks.adaptive_sweep
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BitSchedule, StrategyConfig, run_gradient_based,
                        tree_size, upload_bits)

from .common import M_WORKERS, PAPER_CRITERION

STEPS = 400
ALPHA = 0.3
LAMBDA = 0.01


def regression_setup(p=50, n_per_worker=40, seed=0, noise=0.05):
    key = jax.random.PRNGKey(seed)
    kw, kx, kn = jax.random.split(key, 3)
    w_star = jax.random.normal(kw, (p,))
    X = jax.random.normal(kx, (M_WORKERS, n_per_worker, p)) / np.sqrt(p)
    y = jnp.einsum("mnp,p->mn", X, w_star) + noise * jax.random.normal(
        kn, (M_WORKERS, n_per_worker))
    N = M_WORKERS * n_per_worker

    def loss_fn(params, data):
        Xm, ym = data
        resid = Xm @ params["w"] - ym
        return (0.5 * jnp.sum(resid ** 2) + 0.5 * LAMBDA * jnp.sum(params["w"] ** 2) / M_WORKERS) / N

    return loss_fn, {"w": jnp.zeros((p,))}, (X, y)


def bits_to_reach(result, target: float):
    """Cumulative wire bits at the first iteration whose loss <= target
    (None if never reached)."""
    reached = np.asarray(result.loss) <= target
    if not reached.any():
        return None
    return float(result.cum_bits[int(np.argmax(reached))])


def run(out_rows, results):
    loss_fn, p0, data = regression_setup()
    p = tree_size(p0)

    def laq(schedule=None, bits=4):
        cfg = StrategyConfig(kind="laq", bits=bits, criterion=PAPER_CRITERION,
                             bit_schedule=schedule)
        return run_gradient_based(loss_fn, p0, data, cfg,
                                  steps=STEPS, alpha=ALPHA)

    fixed = {b: laq(bits=b) for b in (2, 4, 8)}
    # fractions of the bootstrap anchor — no per-workload radii.  This
    # radius trajectory collapses to ~0.1 R_0 within ten rounds, so the
    # cheap profile fits: 4-bit bootstrap (th1 >= 1 keeps 8-bit
    # unreachable), 2-bit refinements once R < R_0 / 2.
    rel = dict(threshold_mode="rel", thresholds=(0.5, 2.0))
    radius = laq(BitSchedule(kind="radius", grid=(2, 4, 8), **rel))
    budget_total = 2.0 * p * STEPS           # per-worker: ~2 bits/coord/round
    budget = laq(BitSchedule(kind="budget", grid=(2, 4, 8), **rel,
                             total_bits=budget_total, horizon=STEPS))

    target = float(fixed[4].loss[-1]) + 1e-7
    sweep = {}
    for name, r in [("fixed_b2", fixed[2]), ("fixed_b4", fixed[4]),
                    ("fixed_b8", fixed[8]), ("adaptive_radius", radius),
                    ("adaptive_budget", budget)]:
        btr = bits_to_reach(r, target)
        sweep[name] = dict(final_loss=float(r.loss[-1]),
                           total_bits=float(r.cum_bits[-1]),
                           rounds=int(r.cum_uploads[-1]),
                           bits_to_fixed4_loss=btr,
                           mean_width_late=float(np.asarray(
                               r.mean_bits)[-50:].mean()))
        out_rows.append((f"adaptive_sweep_{name}", float(r.cum_bits[-1]),
                         f"loss={sweep[name]['final_loss']:.3e};"
                         f"bits_to_target={btr}"))
    results["adaptive_sweep"] = sweep

    fixed4_bits = sweep["fixed_b4"]["total_bits"]
    rb = sweep["adaptive_radius"]["bits_to_fixed4_loss"]
    bb = sweep["adaptive_budget"]["bits_to_fixed4_loss"]
    per_worker_cap = budget_total + upload_bits(p, 8, bit_sidecar=True)
    checks = {
        "adaptive(radius) reaches fixed-4 loss with fewer total bits":
            rb is not None and rb < fixed4_bits,
        "adaptive(budget) reaches fixed-4 loss with fewer total bits":
            bb is not None and bb < fixed4_bits,
        "budget controller respects its cumulative allowance":
            float(budget.cum_bits[-1]) / M_WORKERS <= per_worker_cap,
        "late-training width collapses to the bottom of the grid":
            sweep["adaptive_radius"]["mean_width_late"] <= 4.0,
    }
    results["adaptive_sweep/claims"] = checks
    return checks


def main():
    out_rows, results = [], {}
    checks = run(out_rows, results)
    print(f"{'run':24s} {'total bits':>12s} {'bits@fixed4 loss':>17s} "
          f"{'final loss':>12s} {'rounds':>7s}")
    for name, row in results["adaptive_sweep"].items():
        btr = row["bits_to_fixed4_loss"]
        print(f"{name:24s} {row['total_bits']:12.3e} "
              f"{(f'{btr:.3e}' if btr is not None else 'never'):>17s} "
              f"{row['final_loss']:12.6e} {row['rounds']:7d}")
    ok = True
    for k, v in checks.items():
        print(f"[{'PASS' if v else 'FAIL'}] {k}")
        ok &= bool(v)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
