"""Serving-freshness frontier: eval quality vs delta-push bandwidth.

One LAQ trainer (the PR-8 micro-LM recipe: b=8 dense grid, 1/t stepsize,
``AccumulatingSource`` gradient fold) is run ONCE; its parameter
trajectory is then replayed through competing **publishing policies**
(core/replica.py) feeding an inference replica, and each policy is scored
on what the replica fleet actually cares about:

* the replica's held-out eval loss / perplexity at the end of training
  (serving a stale or quantized view must not cost model quality),
* pushed wire bits (the model-delta CDN's bandwidth bill, init snapshot
  included for every policy so comparisons are honest),
* freshness: the worst ``rounds_behind`` any replica ever serves at.

Policies: always-push **float32** (a full resync every round — the
naive weight-sync baseline), always-push **quantized** (b=4 deltas every
round), **lazy quantized** (the tentpole: push only when innovation beats
the rel-anchor threshold, bounded staleness backstop), lazy **adaptive
width** (rel-mode ``BitSchedule`` picks b per push), and the lazy policy
behind a 3-replica fleet with transport delay (``max_delay=2``).

Claims checked:

* **lazy quantized serves within 1.05x of always-push-float32 eval loss**
  (1.10x tiny) — staleness + quantization don't cost quality;
* **at <= 0.25x the pushed bytes** — the bandwidth headline;
* **lazy pushes fewer bytes than always-push quantized** — laziness pays
  on top of quantization;
* **replica == published view bitwise on BOTH wire backends, with
  identical push schedules** — the wire contract under the serve path;
* **a max_staleness resync restores bitwise trainer equality**;
* **freshness stays within the staleness budget** (+ transport delay for
  the delayed fleet);
* a steady-state greedy-decode **tokens/s** row rides along for the
  trajectory record (no claim: CPU CI timing is noise).

Emits ``BENCH_serve.json`` at the repo root (CI serve-smoke runs
``--tiny`` and uploads the artifact; the committed file is a full run).

    PYTHONPATH=src python -m benchmarks.serve_frontier [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CriterionConfig, EtaSchedule, PublishConfig,
                        RoundEngine, StrategyConfig)
from repro.core.adaptive import BitSchedule
from repro.core.engine import AccumulatingSource
from repro.core.replica import (apply_message, init_publisher, init_replica,
                                publish)
from repro.data import lm_worker_corpus
from repro.launch.publish import ReplicaFleet
from repro.models import init_params, lm_loss, lm_worker_loss
from repro.models.config import ModelConfig

STEPS = 150
TINY_STEPS = 40
LOSS_MULT = 1.05
TINY_LOSS_MULT = 1.10
BYTES_MULT = 0.25
ALPHA = 0.5
W = 4
ACCUM = 2
TRAIN_BITS = 8            # the gradient wire's dense-grid floor (PR 8)
PUSH_BITS = 4             # the parameter-delta wire is a separate dial
LAZY_TH = 0.35
MAX_STALENESS = 16

CFG = ModelConfig(name="lm-micro", arch_type="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                  q_chunk=16, kv_chunk=8,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
CRIT = CriterionConfig(D=10, xi=0.08, t_bar=100)
ETA = EtaSchedule(kind="inv_t", t0=30.0)

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_serve.json"))


def _policies(tiny: bool):
    return {
        # a full-precision resync every round: threshold>=1 never lazily
        # pushes, max_staleness=0 tolerates no skip
        "float32_push": PublishConfig(threshold=1.5, max_staleness=0),
        "quant_push": PublishConfig(bits=PUSH_BITS, threshold=0.0),
        "lazy_quant": PublishConfig(bits=PUSH_BITS, threshold=LAZY_TH,
                                    max_staleness=MAX_STALENESS),
        "lazy_adaptive": PublishConfig(
            threshold=LAZY_TH, max_staleness=MAX_STALENESS,
            bit_schedule=BitSchedule(kind="radius", grid=(2, 4, 8),
                                     threshold_mode="rel",
                                     thresholds=(0.05, 0.5))),
    }


def _train_trajectory(steps: int):
    """The single trainer run every policy replays (host-side list of
    per-round param pytrees; the micro LM keeps this small)."""
    engine = RoundEngine(
        AccumulatingSource(lm_worker_loss(CFG, W),
                           lm_worker_corpus(0, W, 16, 16, CFG.vocab),
                           deterministic=True, accum=ACCUM, scale=1.0),
        StrategyConfig(kind="laq", bits=TRAIN_BITS, per_leaf_radius=True,
                       criterion=CRIT, eta_schedule=ETA),
        alpha=ALPHA)
    params0 = init_params(jax.random.PRNGKey(0), CFG)
    step = jax.jit(engine.round)
    carry = engine.init_carry(params0)
    traj = []
    for _ in range(steps):
        carry, _ = step(carry, None)
        traj.append(carry[0])
    return params0, traj


def _replay(name: str, pcfg: PublishConfig, params0, traj, eval_loss, *,
            n_replicas=1, max_delay=0):
    """Run one publishing policy over the trajectory; score the last
    replica the fleet would serve from."""
    st = init_publisher(params0, pcfg)
    fleet = ReplicaFleet(params0, n_replicas, pcfg, max_delay=max_delay)
    max_behind = 0
    resync_exact = None
    for params in traj:
        msg, st = publish(pcfg, st, params)
        fleet.deliver(msg)
        max_behind = max(max_behind, max(fleet.freshness()))
        if msg is not None and not hasattr(msg, "payloads") and max_delay == 0:
            # a resync just landed on a synchronous fleet: bitwise trainer
            # equality is the whole point of the escape hatch
            exact = all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(fleet.replicas[0].params),
                                jax.tree.leaves(params)))
            resync_exact = exact if resync_exact is None \
                else (resync_exact and exact)
    loss = float(eval_loss(fleet.replicas[0].params))
    return dict(policy=name, bits=st.bits_sent, n_pushes=st.n_pushes,
                n_resyncs=st.n_resyncs, max_rounds_behind=max_behind,
                eval_loss=loss, eval_ppl=float(np.exp(min(loss, 30.0))),
                resync_exact=resync_exact, n_replicas=n_replicas,
                max_delay=max_delay)


def _bitwise_both_backends(params0, traj):
    """The wire contract on the serve path: both backends produce the same
    push schedule and a replica that equals the published view bitwise."""
    outcomes = {}
    for backend in ("reference", "fused"):
        pcfg = PublishConfig(bits=PUSH_BITS, threshold=LAZY_TH,
                             max_staleness=MAX_STALENESS,
                             wire_backend=backend)
        st = init_publisher(params0, pcfg)
        rep = init_replica(params0)
        sched, ok = [], True
        for params in traj:
            msg, st = publish(pcfg, st, params)
            rep = apply_message(rep, msg, pcfg)
            sched.append(None if msg is None
                         else "p" if hasattr(msg, "payloads") else "r")
            ok &= all(np.array_equal(np.asarray(a), np.asarray(b))
                      for a, b in zip(jax.tree.leaves(rep.params),
                                      jax.tree.leaves(st.theta_pub)))
        outcomes[backend] = (sched, ok, st.bits_sent)
    scheds_equal = outcomes["reference"][0] == outcomes["fused"][0]
    bitwise = outcomes["reference"][1] and outcomes["fused"][1]
    bits_equal = outcomes["reference"][2] == outcomes["fused"][2]
    return scheds_equal and bits_equal, bitwise


def _decode_tokens_per_s(params, tokens=16, batch=4, prompt_len=16):
    """Steady-state greedy decode rate on the final served weights (jit
    warmup excluded; single-device: the mesh timing lives in the example)."""
    from repro.launch.serve import jit_serve
    prefill_fn, decode_fn = jit_serve(CFG, prompt_len + tokens)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, CFG.vocab)
    tok, cache = prefill_fn(params, prompts)          # warmup prefill
    jax.block_until_ready(decode_fn(params, cache, tok))  # warmup (eats cache)
    tok, cache = prefill_fn(params, prompts)
    t0 = time.time()
    with jax.transfer_guard("disallow"):
        for _ in range(tokens):
            tok, cache = decode_fn(params, cache, tok)
    jax.block_until_ready(tok)
    return batch * tokens / (time.time() - t0)


def run(out_rows, results, tiny: bool = False):
    steps = TINY_STEPS if tiny else STEPS
    params0, traj = _train_trajectory(steps)

    held_out = lm_worker_corpus(1, 1, 32, 16, CFG.vocab)
    eval_batch = jax.tree.map(lambda l: l[0], held_out)
    eval_loss = jax.jit(lambda p: lm_loss(p, eval_batch, CFG))

    rows = [_replay(name, pcfg, params0, traj, eval_loss)
            for name, pcfg in _policies(tiny).items()]
    rows.append(_replay("lazy_quant_fleet",
                        PublishConfig(bits=PUSH_BITS, threshold=LAZY_TH,
                                      max_staleness=MAX_STALENESS),
                        params0, traj, eval_loss, n_replicas=3, max_delay=2))
    by = {r["policy"]: r for r in rows}

    toks_per_s = _decode_tokens_per_s(init_replica(traj[-1]).params)
    rows.append(dict(policy="decode_rate", tokens_per_s=float(toks_per_s)))

    for r in rows[:-1]:
        out_rows.append((f"serve_{r['policy']}", float(r["bits"]),
                         f"ppl={r['eval_ppl']:.3f};behind<={r['max_rounds_behind']};"
                         f"pushes={r['n_pushes']}+{r['n_resyncs']}rs"))
    out_rows.append(("serve_decode_rate", float(toks_per_s), "tok/s"))

    f32, lazy, quant = by["float32_push"], by["lazy_quant"], by["quant_push"]
    mult = TINY_LOSS_MULT if tiny else LOSS_MULT
    sched_ok, bitwise_ok = _bitwise_both_backends(params0, traj)
    checks = {
        "lazy quantized publishing serves within "
        f"{mult}x of always-push-float32 eval loss":
            lazy["eval_loss"] <= mult * f32["eval_loss"],
        "lazy quantized pushes <= 0.25x the float32 bytes":
            lazy["bits"] <= BYTES_MULT * f32["bits"],
        "laziness pays on top of quantization: lazy < always-push bytes":
            lazy["bits"] < quant["bits"],
        "replica == published view bitwise on both wire backends":
            bitwise_ok,
        "both wire backends cut identical push schedules and bits":
            sched_ok,
        "every max_staleness resync restored bitwise trainer equality":
            None if lazy["n_resyncs"] == 0 and f32["n_resyncs"] == 0
            else bool((lazy["resync_exact"] in (None, True))
                      and (f32["resync_exact"] in (None, True))
                      and (lazy["n_resyncs"] + f32["n_resyncs"]) > 0),
        "freshness stays within the staleness budget (+ transport delay)":
            lazy["max_rounds_behind"] <= MAX_STALENESS
            and by["lazy_quant_fleet"]["max_rounds_behind"]
            <= MAX_STALENESS + 2,
        "adaptive width serves the same quality band as fixed b=4":
            by["lazy_adaptive"]["eval_loss"] <= mult * f32["eval_loss"],
    }
    results["serve_frontier"] = dict(steps=steps, push_bits=PUSH_BITS,
                                     threshold=LAZY_TH,
                                     max_staleness=MAX_STALENESS,
                                     **{r["policy"]: r for r in rows})
    results["serve_frontier/claims"] = checks

    with open(ROOT_JSON, "w") as fh:
        json.dump({"tiny": tiny, "steps": steps,
                   "rows": rows, "checks": checks}, fh, indent=1)
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer trainer rounds, looser loss band")
    args = ap.parse_args()
    out_rows, results = [], {}
    checks = run(out_rows, results, tiny=args.tiny)
    f = results["serve_frontier"]
    print(f"{'policy':17s} {'eval ppl':>9s} {'Mbits':>8s} {'pushes':>7s} "
          f"{'resyncs':>8s} {'behind':>7s}")
    for name in ("float32_push", "quant_push", "lazy_quant", "lazy_adaptive",
                 "lazy_quant_fleet"):
        r = f[name]
        print(f"{name:17s} {r['eval_ppl']:9.3f} {r['bits']/1e6:8.3f} "
              f"{r['n_pushes']:7d} {r['n_resyncs']:8d} "
              f"{r['max_rounds_behind']:7d}")
    print(f"decode: {f['decode_rate']['tokens_per_s']:,.0f} tok/s "
          f"(steady-state greedy, no claim)")
    ok = True
    for k, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {k}")
        ok &= v is None or bool(v)
    print(f"-> {ROOT_JSON}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
