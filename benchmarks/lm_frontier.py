"""Bits-to-perplexity frontier on a real language model.

The headline LM experiment of this repo: the full LAQ protocol trains a
tiny next-token transformer (the same micro config the LM test tier pins)
end to end through the engine's ``AccumulatingSource`` — each worker's
local corpus streamed through the gradient-accumulation fold — and the
frontier compares what each method pays on the wire to reach the QGD
perplexity floor.  ``exp(loss)`` is perplexity throughout
(``lm_worker_loss`` normalizes so the engine's global objective is the
global mean token cross-entropy).

Workload facts this frontier documents (all seeded, deterministic rows use
the full local corpus so the runs are exactly reproducible):

* the LM pins the dense grid at **b=8**: at b=4 the per-leaf quantization
  error inflates the RHS of (7a) until every round skips and the run
  diverges — which is also why the radius-scheduled A-LAQ row (width
  collapse as R decays) stalls above the floor here instead of harvesting
  slack like it does on the strongly convex regression;
* both lazy methods need the **1/t stepsize** to skip at the floor: with a
  constant alpha the aggregate keeps oscillating, the innovation never
  decays, and LAQ degenerates to QGD-with-occasional-skips.

Claims checked:

* **LAQ reaches the QGD floor target and spends fewer total wire bits**
  (tiny + full);
* **bits-to-target: LAQ < 0.5x QGD** (full horizon only; the tiny run's
  loose target is reached before laziness pays, so tiny records SKIP);
* **A-LAQ's width collapse stalls above the floor** fixed-b8 LAQ reaches
  (full only) — the negative result that pins the b=8 grid requirement;
* **EF-topk reaches the target at < 0.5x LAQ's bits-to-target** — at 5%
  density the sparse payload dominates even LAQ's skipping;
* **SLAQ (WK rule, minibatch source) skips and spends fewer total bits
  than QSGD** while landing within 1.2x of the QSGD tail loss;
* **training works**: final LAQ perplexity is far below the initial one.

Emits ``BENCH_lm.json`` at the repo root (CI lm-smoke runs the ``--tiny``
variant and uploads it as an artifact; the committed file is a full run).

    PYTHONPATH=src python -m benchmarks.lm_frontier [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CriterionConfig, EtaSchedule, RoundEngine,
                        StrategyConfig)
from repro.core.adaptive import BitSchedule
from repro.core.engine import AccumulatingSource
from repro.data import lm_worker_corpus
from repro.models import init_params, lm_worker_loss
from repro.models.config import ModelConfig

from .lasg_frontier import first_reach

STEPS = 150
TINY_STEPS = 50           # CI smoke: before laziness pays off, so tiny
TINY_TARGET_MULT = 1.10   # gates on the loose target + total-bits claims
TARGET_MULT = 1.025
ALPHA = 0.5
W = 4
ACCUM = 2                 # microbatches per worker through the fold
BITS = 8                  # the dense-grid floor this workload needs
EF_K = 0.05

CFG = ModelConfig(name="lm-micro", arch_type="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                  q_chunk=16, kv_chunk=8,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
CRIT = CriterionConfig(D=10, xi=0.08, t_bar=100)
ETA = EtaSchedule(kind="inv_t", t0=30.0)

ROOT_JSON = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, "BENCH_lm.json"))


def _methods():
    base = dict(bits=BITS, per_leaf_radius=True, criterion=CRIT,
                eta_schedule=ETA)
    det = {
        "qgd": StrategyConfig(kind="qgd", **base),
        "laq": StrategyConfig(kind="laq", **base),
        "alaq": StrategyConfig(kind="laq", **base, bit_schedule=BitSchedule(
            kind="radius", grid=(2, 4, 8), threshold_mode="rel",
            thresholds=(0.05, 0.5))),
        "ef_topk": StrategyConfig(kind="laq", bits=4, per_leaf_radius=True,
                                  criterion=CRIT, eta_schedule=ETA,
                                  compressor="topk", compressor_k=EF_K,
                                  error_feedback=True),
    }
    sto = {
        "qsgd": StrategyConfig(kind="qgd", bits=4, per_leaf_radius=True,
                               criterion=CRIT, eta_schedule=ETA),
        "slaq": StrategyConfig(kind="laq", bits=4, per_leaf_radius=True,
                               criterion=CRIT, eta_schedule=ETA,
                               lazy_rule="lasg_wk"),
    }
    return det, sto


def run(out_rows, results, tiny: bool = False):
    corpus = lm_worker_corpus(0, W, 16, 16, CFG.vocab)
    loss_fn = lm_worker_loss(CFG, W)
    params = init_params(jax.random.PRNGKey(0), CFG)
    steps = TINY_STEPS if tiny else STEPS

    def det_source():
        return AccumulatingSource(loss_fn, corpus, deterministic=True,
                                  accum=ACCUM, scale=1.0)

    def sto_source():
        return AccumulatingSource(loss_fn, corpus, batch=8, seed=0,
                                  accum=ACCUM, scale=1.0)

    det_cfgs, sto_cfgs = _methods()
    runs = {name: RoundEngine(det_source(), cfg, alpha=ALPHA).run(params,
                                                                  steps)
            for name, cfg in det_cfgs.items()}
    runs.update({name: RoundEngine(sto_source(), cfg, alpha=ALPHA)
                 .run(params, steps) for name, cfg in sto_cfgs.items()})

    floor = float(np.mean(np.asarray(runs["qgd"].loss)[-5:]))
    target = (TINY_TARGET_MULT if tiny else TARGET_MULT) * floor

    frontier = {}
    for name, r in runs.items():
        at = first_reach(r, target)
        tail = float(np.mean(np.asarray(r.loss)[-5:]))
        frontier[name] = dict(
            final_loss=float(r.loss[-1]),
            final_ppl=float(np.exp(min(float(r.loss[-1]), 30.0))),
            tail_loss=tail,
            total_uploads=int(r.cum_uploads[-1]),
            total_bits=float(r.cum_bits[-1]),
            uploads_to_target=None if at is None else at[0],
            bits_to_target=None if at is None else at[1])
        out_rows.append((f"lm_frontier_{name}", float(r.cum_bits[-1]),
                         f"ppl={frontier[name]['final_ppl']:.3f};"
                         f"to_target={at}"))

    def bits_to(name):
        v = frontier[name]["bits_to_target"]
        return np.inf if v is None else v

    init_ppl = float(np.exp(float(runs["laq"].loss[0])))
    checks = {
        "LAQ reaches the QGD floor target in fewer total wire bits":
            frontier["laq"]["bits_to_target"] is not None
            and frontier["laq"]["total_bits"] < frontier["qgd"]["total_bits"],
        # the strongest form needs the full horizon: the tiny target is
        # loose enough that QGD reaches it before laziness pays
        "bits-to-target: LAQ < 0.5x QGD":
            None if tiny else bits_to("laq") < 0.5 * bits_to("qgd"),
        # negative result: on the LM the radius schedule's width collapse
        # (R decays -> grid drops below b=8) stalls above the floor that
        # fixed-b8 LAQ reaches — the workload pins the grid width
        "A-LAQ width collapse stalls above the floor LAQ reaches":
            None if tiny else (frontier["alaq"]["bits_to_target"] is None
                               and frontier["laq"]["bits_to_target"]
                               is not None),
        "EF-topk reaches the target at < 0.5x LAQ's bits-to-target":
            bits_to("ef_topk") < 0.5 * bits_to("laq"),
        "SLAQ skips and spends fewer total bits than QSGD":
            frontier["slaq"]["total_uploads"] < W * steps
            and frontier["slaq"]["total_bits"]
            < frontier["qsgd"]["total_bits"],
        "SLAQ tail loss lands within 1.2x of the QSGD tail":
            frontier["slaq"]["tail_loss"]
            <= 1.2 * frontier["qsgd"]["tail_loss"],
        "LM actually trains: final LAQ perplexity << initial":
            frontier["laq"]["final_ppl"] < 0.25 * init_ppl,
    }
    results["lm_frontier"] = dict(target_loss=target, qgd_floor=floor,
                                  floor_ppl=float(np.exp(floor)),
                                  init_ppl=init_ppl, steps=steps,
                                  accum=ACCUM, workers=W, **frontier)
    results["lm_frontier/claims"] = checks

    with open(ROOT_JSON, "w") as f:
        json.dump({"tiny": tiny, "steps": steps, "target_loss": target,
                   "qgd_floor": floor, "floor_ppl": float(np.exp(floor)),
                   "rows": [dict(name=n, **row)
                            for n, row in frontier.items()],
                   "checks": checks}, f, indent=1)
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer rounds, looser target")
    args = ap.parse_args()
    out_rows, results = [], {}
    checks = run(out_rows, results, tiny=args.tiny)
    f = results["lm_frontier"]
    print(f"target loss = {f['target_loss']:.4f} "
          f"({TINY_TARGET_MULT if args.tiny else TARGET_MULT}x QGD floor "
          f"{f['qgd_floor']:.4f} = ppl {f['floor_ppl']:.3f}, "
          f"steps={f['steps']}, W={W}, accum={ACCUM})")
    print(f"{'method':9s} {'final ppl':>10s} {'uploads':>8s} "
          f"{'bits':>11s} {'uploads@tgt':>12s} {'bits@tgt':>11s}")
    for name in ("qgd", "laq", "alaq", "ef_topk", "qsgd", "slaq"):
        row = f[name]
        ut, bt = row["uploads_to_target"], row["bits_to_target"]
        print(f"{name:9s} {row['final_ppl']:10.3f} "
              f"{row['total_uploads']:8d} {row['total_bits']:11.3e} "
              f"{(str(ut) if ut is not None else 'never'):>12s} "
              f"{(f'{bt:.3e}' if bt is not None else 'never'):>11s}")
    ok = True
    for kk, v in checks.items():
        print(f"[{'SKIP' if v is None else 'PASS' if v else 'FAIL'}] {kk}")
        ok &= v is None or bool(v)
    print(f"-> {ROOT_JSON}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
