"""Fault injection + fault-tolerant aggregation (core/faults.py,
core/defense.py).

Covers the deterministic fault streams, the corruption / crash primitives,
the rejected-upload accounting contract (a rejection is masked exactly like
a lazy skip, but its wire bits are still paid), the crash reconciliation
invariant ``server_agg == sum_m qhat_m``, the robust aggregators, and the
divergence watchdog's rollback + escalation loop.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, DefenseConfig, DefenseState,
                        FaultConfig, RoundEngine, StrategyConfig,
                        WatchdogConfig, apply_crashes, bitflip_keys,
                        corrupt_grads, corruption_mask, crash_mask,
                        defense_step, flip_wire_codes, init_comm_state,
                        init_defense_state, robust_aggregate,
                        run_gradient_based, run_with_watchdog)
from repro.core.engine import FullBatchSource
from repro.core.wire import get_backend

from test_engine_parity import quadratic_problem

CRIT = CriterionConfig(D=10, xi=0.08, t_bar=20)
LAQ = StrategyConfig(kind="laq", bits=4, criterion=CRIT)


def run_laq(steps=60, alpha=0.3, **kw):
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(**kw)
    return run_gradient_based(loss_fn, p0, data, cfg, steps=steps,
                              alpha=alpha)


# ---------------------------------------------------------------------------
# Fault streams: deterministic, independent, correctly distributed.
# ---------------------------------------------------------------------------

def test_fault_streams_deterministic_and_disjoint():
    fc = FaultConfig(corrupt_p=0.3, crash_p=0.3)
    a = np.asarray(corruption_mask(fc, 7, 64))
    np.testing.assert_array_equal(a, np.asarray(corruption_mask(fc, 7, 64)))
    # corruption and crash draw from different streams at the same step
    b = np.asarray(crash_mask(fc, 7, 64))
    assert not np.array_equal(a, b)
    # different seeds decorrelate
    c = np.asarray(corruption_mask(fc._replace(fault_seed=1), 7, 64))
    assert not np.array_equal(a, c)
    # frequency sanity over many rounds
    draws = np.stack([np.asarray(corruption_mask(fc, k, 64))
                      for k in range(30)])
    assert 0.2 < draws.mean() < 0.4
    ks = bitflip_keys(fc, 3, 8)
    assert ks.shape[0] == 8
    np.testing.assert_array_equal(np.asarray(ks),
                                  np.asarray(bitflip_keys(fc, 3, 8)))


def test_config_family_predicates():
    assert not FaultConfig().active
    assert FaultConfig(corrupt_p=0.1).grad_faulty
    assert not FaultConfig(corrupt_p=0.1).wire_faulty
    bf = FaultConfig(corrupt_p=0.1, corrupt_kind="bitflip")
    assert bf.wire_faulty and not bf.grad_faulty
    assert FaultConfig(crash_p=0.1).crashy and FaultConfig(crash_p=0.1).active


def test_corrupt_grads_kinds():
    g = {"w": jnp.ones((4, 3)), "b": 2.0 * jnp.ones((4,))}
    mask = jnp.array([True, False, True, False])
    for kind, expect in [("nan", np.nan), ("inf", np.inf),
                         ("sign_flip", -1.0), ("scale", 50.0)]:
        out = corrupt_grads(g, mask, FaultConfig(corrupt_p=1.0,
                                                 corrupt_kind=kind))
        w = np.asarray(out["w"])
        if kind == "nan":
            assert np.all(np.isnan(w[0])) and np.all(np.isnan(w[2]))
        else:
            np.testing.assert_allclose(w[0], expect)
        # untouched workers keep the honest gradient
        np.testing.assert_array_equal(w[1], np.ones((3,)))
        np.testing.assert_array_equal(np.asarray(out["b"])[3], 2.0)


def test_flip_wire_codes_stays_on_grid_and_flips_expected_fraction():
    key = jax.random.PRNGKey(0)
    g = {"x": jax.random.normal(key, (256,))}
    qhat = {"x": jnp.zeros((256,))}
    rt = get_backend("reference").roundtrip(g, qhat, 4)
    flipped = flip_wire_codes(rt.delta, rt.R_tree, 4,
                              jax.random.PRNGKey(7), 0.25)
    d0, d1 = np.asarray(rt.delta["x"]), np.asarray(flipped["x"])
    changed = np.mean(~np.isclose(d0, d1))
    assert 0.1 < changed < 0.4          # ~25% of codes moved
    # every flipped value is still a representable code: round-tripping the
    # corrupted delta through the inverse maps is the identity
    from repro.core.wire import codes_of_delta, delta_of_codes
    R = rt.R_tree["x"]
    again = delta_of_codes(codes_of_delta(flipped["x"], R, 4), R, 4)
    np.testing.assert_allclose(np.asarray(again), d1, rtol=1e-6)
    # an MSB flip moves a coordinate by 2*tau*R*2^(b-1) exactly
    tau = 1.0 / (2.0 ** 4 - 1.0)
    step = 2.0 * tau * float(R) * 8
    moved = np.abs(d1 - d0)[~np.isclose(d0, d1)]
    np.testing.assert_allclose(moved, step, rtol=1e-5)


# ---------------------------------------------------------------------------
# Crash-restart: state loss + reconciliation invariant.
# ---------------------------------------------------------------------------

def _comm_after_some_rounds(cfg, steps=10):
    loss_fn, p0, data = quadratic_problem()
    src = FullBatchSource(loss_fn, data)
    eng = RoundEngine(src, cfg, alpha=0.3)
    carry, _ = eng.run_from(eng.init_carry(p0), steps)
    return eng, carry


def _sum_qhat(cst):
    return jax.tree.map(lambda q: jnp.sum(q.astype(jnp.float32), axis=0),
                        cst.qhat)


def test_apply_crashes_resets_and_reconciles():
    cfg = LAQ._replace(lazy_rule="lasg_wk2", grad_mode="svrg",
                       error_feedback=True, compressor="topk")
    eng, carry = _comm_after_some_rounds(cfg)
    params, cst, _ = carry
    grads = jax.tree.map(
        lambda l: jnp.ones_like(l, jnp.float32), cst.qhat)
    mask = jnp.array([True] + [False] * 9)
    out = apply_crashes(cst, mask, params, grads, cfg, reconcile=True)
    # worker 0 lost everything; worker 1 kept everything
    for tree in (out.qhat, out.error.residual):
        leaf = jax.tree.leaves(tree)[0]
        assert float(jnp.sum(jnp.abs(leaf[0]))) == 0.0
    np.testing.assert_array_equal(np.asarray(out.qhat["x"][1]),
                                  np.asarray(cst.qhat["x"][1]))
    assert float(out.eps_hat_sq[0]) == 0.0
    assert int(out.clocks[0]) == cfg.criterion.t_bar
    assert float(out.lazy.stat_count[0]) == 0.0
    # restarted snapshots: theta_last / svrg anchor at the current iterate,
    # svrg mu at this round's gradient
    np.testing.assert_allclose(np.asarray(out.lazy.theta_last["x"][0]),
                               np.asarray(params["x"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.svrg.theta_anchor["x"][0]),
                               np.asarray(params["x"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.svrg.mu_anchor["x"][0]), 1.0)
    # the reconciled server keeps the recursion invariant exactly
    np.testing.assert_allclose(np.asarray(out.server_agg["x"]),
                               np.asarray(_sum_qhat(out)["x"]), atol=1e-4)
    # server-side ledgers survive (the server never lost them)
    np.testing.assert_array_equal(np.asarray(out.bits_spent),
                                  np.asarray(cst.bits_spent))
    assert int(out.total_uploads) == int(cst.total_uploads)


def test_naive_crash_breaks_recursion_invariant():
    eng, carry = _comm_after_some_rounds(LAQ)
    params, cst, _ = carry
    grads = jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), cst.qhat)
    mask = jnp.array([True] + [False] * 9)
    out = apply_crashes(cst, mask, params, grads, LAQ, reconcile=False)
    ghost = np.asarray(cst.qhat["x"][0])
    drift = np.asarray(out.server_agg["x"]) - np.asarray(_sum_qhat(out)["x"])
    np.testing.assert_allclose(drift, ghost, atol=1e-4)


def test_crash_run_recursion_invariant_end_to_end():
    res_rec = run_laq(faults=FaultConfig(crash_p=0.05),
                      defense=DefenseConfig(reconcile_crashes=True))
    assert np.all(np.isfinite(np.asarray(res_rec.loss)))
    # the engine's own final state keeps the invariant under crashes
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(faults=FaultConfig(crash_p=0.05))
    eng = RoundEngine(FullBatchSource(loss_fn, data), cfg, alpha=0.3)
    carry, _ = eng.run_from(eng.init_carry(p0), 40)
    _, cst, _ = carry
    np.testing.assert_allclose(np.asarray(cst.server_agg["x"]),
                               np.asarray(_sum_qhat(cst)["x"]), atol=1e-3)


# ---------------------------------------------------------------------------
# Defense: validation gate semantics + the rejected-upload accounting.
# ---------------------------------------------------------------------------

def test_defense_step_finite_check_and_gate():
    dc = DefenseConfig(validate=True, gate_mult=4.0)
    ds = jax.tree.map(lambda x: x[0], init_defense_state(dc, 1))
    up = jnp.array(True)
    # warm-up: finite accepted (EMA seeds), non-finite rejected
    acc, sc, ds1 = defense_step(dc, ds, jnp.float32(2.0), jnp.float32(0.1), up)
    assert bool(acc) and float(sc) == 1.0 and float(ds1.norm_count) == 1.0
    acc, _, _ = defense_step(dc, ds, jnp.float32(jnp.nan), jnp.float32(0.1), up)
    assert not bool(acc)
    # a NaN eps-hat moment is rejected even when the payload energy is finite
    # (the quantizer's R>0 guard turns a NaN gradient into a zero delta)
    acc, _, _ = defense_step(dc, ds, jnp.float32(0.0), jnp.float32(jnp.nan), up)
    assert not bool(acc)
    # warmed gate: in-band accepted, out-of-band rejected + ledger advances
    acc, _, ds2 = defense_step(dc, ds1, jnp.float32(3.0), jnp.float32(0.1), up)
    assert bool(acc)
    acc, _, ds3 = defense_step(dc, ds1, jnp.float32(1e6), jnp.float32(0.1), up)
    assert not bool(acc) and int(ds3.rejects) == 1
    # the EMA only advances on committed uploads
    np.testing.assert_allclose(float(ds3.norm_ema), float(ds1.norm_ema))
    # a skipped round (no transmission) neither commits nor rejects
    acc, _, ds4 = defense_step(dc, ds1, jnp.float32(1e6), jnp.float32(0.1),
                               jnp.array(False))
    assert int(ds4.rejects) == 0
    np.testing.assert_allclose(float(ds4.norm_count), float(ds1.norm_count))


def test_defense_clip_scales_to_radius():
    dc = DefenseConfig(clip_mult=2.0)
    ds = jax.tree.map(lambda x: x[0], init_defense_state(dc, 1))
    _, _, ds1 = defense_step(dc, ds, jnp.float32(1.0), jnp.float32(0.0),
                             jnp.array(True))
    acc, sc, _ = defense_step(dc, ds1, jnp.float32(100.0), jnp.float32(0.0),
                              jnp.array(True))
    assert bool(acc)                       # clip does not reject
    # post-clip energy == clip_mult * ema exactly
    np.testing.assert_allclose(100.0 * float(sc) ** 2, 2.0 * 1.0, rtol=1e-5)


def test_rejected_upload_masked_like_skip_but_pays_bits():
    """The central accounting contract: rejection == forced skip + honest
    bits.  Inf-corrupted uploads are rejected by validation; the corrupted
    worker's qhat must stay frozen, its clock must grow, and its wire bits
    must still be charged."""
    fc = FaultConfig(corrupt_p=0.3, corrupt_kind="inf", fault_seed=2)
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(faults=fc, defense=DefenseConfig(validate=True))
    eng = RoundEngine(FullBatchSource(loss_fn, data), cfg, alpha=0.3)
    carry = eng.init_carry(p0)
    hit_reject = False
    for step in range(12):
        _, cst, _ = carry
        corrupted = np.asarray(corruption_mask(fc, step, 10))
        before = {"qhat": np.asarray(cst.qhat["x"]),
                  "eps": np.asarray(cst.eps_hat_sq),
                  "clocks": np.asarray(cst.clocks),
                  "bits": np.asarray(cst.bits_spent),
                  "rejects": np.asarray(cst.defense.rejects),
                  "agg": np.asarray(cst.server_agg["x"])}
        carry, _ = eng.run_from(carry, 1)
        _, cst2, _ = carry
        rejected = np.asarray(cst2.defense.rejects) > before["rejects"]
        assert not np.any(rejected & ~corrupted)      # only corrupt rejected
        for m in np.nonzero(rejected)[0]:
            hit_reject = True
            # masked exactly like a lazy skip ...
            np.testing.assert_array_equal(np.asarray(cst2.qhat["x"])[m],
                                          before["qhat"][m])
            assert float(cst2.eps_hat_sq[m]) == before["eps"][m]
            assert int(cst2.clocks[m]) == before["clocks"][m] + 1
            # ... except the transmission bits are still charged
            assert float(cst2.bits_spent[m]) > before["bits"][m]
        # the server aggregate stays finite throughout
        assert np.all(np.isfinite(np.asarray(cst2.server_agg["x"])))
    assert hit_reject                      # the scenario actually fired


def test_total_uploads_counts_rejected_transmissions():
    fc = FaultConfig(corrupt_p=0.3, corrupt_kind="inf", fault_seed=2)
    res_def = run_laq(steps=30, faults=fc,
                      defense=DefenseConfig(validate=True))
    # uploads (transmissions) include the rejected ones: the defended run
    # pays at least as many as the clean run
    res_clean = run_laq(steps=30)
    assert float(res_def.cum_uploads[-1]) >= float(res_clean.cum_uploads[-1])
    assert np.all(np.isfinite(np.asarray(res_def.loss)))


def test_defense_inactive_is_bitwise_noop():
    a = run_laq()
    b = run_laq(defense=DefenseConfig(validate=True, gate_mult=6.0))
    np.testing.assert_array_equal(np.asarray(a.loss), np.asarray(b.loss))
    np.testing.assert_array_equal(np.asarray(a.cum_bits),
                                  np.asarray(b.cum_bits))


# ---------------------------------------------------------------------------
# Robust aggregation.
# ---------------------------------------------------------------------------

def test_robust_aggregate_median_and_trimmed_mean():
    committed = jnp.array([True, True, True, True, True])
    d = {"x": jnp.array([[1.0], [2.0], [3.0], [4.0], [100.0]])}
    med = robust_aggregate("median", d, committed, 0.2)
    np.testing.assert_allclose(np.asarray(med["x"]), [15.0])      # 3 * 5
    tm = robust_aggregate("trimmed_mean", d, committed, 0.2)
    np.testing.assert_allclose(np.asarray(tm["x"]), [15.0])       # mean(2,3,4)*5
    # non-committed lanes are ignored, not averaged in
    committed2 = jnp.array([True, True, True, True, False])
    d2 = {"x": jnp.array([[1.0], [2.0], [3.0], [4.0], [1e30]])}
    tm2 = robust_aggregate("trimmed_mean", d2, committed2, 0.2)
    np.testing.assert_allclose(np.asarray(tm2["x"]), [10.0])      # mean(2,3)*4
    # NaNs among the committed sort last and are trimmed as the largest
    d3 = {"x": jnp.array([[1.0], [2.0], [3.0], [4.0], [jnp.nan]])}
    tm3 = robust_aggregate("trimmed_mean", d3, committed, 0.2)
    np.testing.assert_allclose(np.asarray(tm3["x"]), [15.0])
    # degenerate cohort (n <= 2t) degrades to the plain masked sum
    few = jnp.array([True, False, False, False, False])
    tm4 = robust_aggregate("trimmed_mean", d, few, 0.2)
    np.testing.assert_allclose(np.asarray(tm4["x"]), [1.0])


def test_trimmed_mean_run_bounds_byzantine_damage():
    fc = FaultConfig(corrupt_p=0.15, corrupt_kind="scale",
                     corrupt_scale=-40.0)
    undef = run_laq(kind="qgd", faults=fc)
    trim = run_laq(kind="qgd", faults=fc, aggregator="trimmed_mean",
                   trim_frac=0.2)
    # the attack visibly damages the plain sum; trimming bounds it
    assert float(np.nanmax(np.asarray(undef.loss))) \
        > 10.0 * float(np.nanmax(np.asarray(trim.loss)))


# ---------------------------------------------------------------------------
# Watchdog: rollback + escalation.
# ---------------------------------------------------------------------------

def test_watchdog_rolls_back_and_escalates(tmp_path):
    loss_fn, p0, data = quadratic_problem()
    src = FullBatchSource(loss_fn, data)
    cfg = LAQ._replace(faults=FaultConfig(corrupt_p=0.1, corrupt_kind="inf"))
    eng = RoundEngine(src, cfg, alpha=0.3)

    def escalate(engine):
        cfg2 = engine.cfg._replace(defense=DefenseConfig(validate=True))
        return RoundEngine(src, cfg2, alpha=0.3)

    res, log, carry = run_with_watchdog(
        eng, p0, 60, ckpt_path=str(tmp_path / "wd.npz"),
        wd=WatchdogConfig(chunk=10), escalate=escalate)
    assert len(log["rollbacks"]) >= 1 and not log["gave_up"]
    assert log["wasted_rounds"] >= 10 and log["wasted_bits"] > 0
    loss = np.asarray(res.loss)
    assert loss.shape[0] == 60 and np.all(np.isfinite(loss))
    # the surviving trajectory converges (the escalated defense holds)
    assert loss[-1] < loss[0]
    # the final carry holds the defense ledger with actual rejections
    _, cst, _ = carry
    assert cst.defense.rejects is not None
    assert int(jnp.sum(cst.defense.rejects)) >= 1


def test_watchdog_healthy_run_is_single_pass(tmp_path):
    loss_fn, p0, data = quadratic_problem()
    eng = RoundEngine(FullBatchSource(loss_fn, data), LAQ, alpha=0.3)
    res, log, _ = run_with_watchdog(eng, p0, 30,
                                    ckpt_path=str(tmp_path / "wd.npz"),
                                    wd=WatchdogConfig(chunk=10))
    assert log["rollbacks"] == [] and log["wasted_rounds"] == 0
    ref = run_laq(steps=30)
    np.testing.assert_array_equal(np.asarray(res.loss), np.asarray(ref.loss))
    np.testing.assert_array_equal(np.asarray(res.cum_bits),
                                  np.asarray(ref.cum_bits))


def test_watchdog_gives_up_without_escalation(tmp_path):
    # deterministic fault streams: a plain replay hits the identical fault,
    # so an inescapable divergence must end in gave_up, not an endless loop
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(faults=FaultConfig(corrupt_p=0.5, corrupt_kind="inf"))
    eng = RoundEngine(FullBatchSource(loss_fn, data), cfg, alpha=0.3)
    res, log, _ = run_with_watchdog(eng, p0, 40,
                                    ckpt_path=str(tmp_path / "wd.npz"),
                                    wd=WatchdogConfig(chunk=10,
                                                      max_rollbacks=2))
    assert log["gave_up"] and len(log["rollbacks"]) == 3
