"""Paper-contract convergence tier (marker: ``contracts``).

Each test here pins one *quantitative* convergence claim of the
variance-reduced stochastic subsystem (LASG, Chen et al. 2020; the
sparse/adaptive-SGD variance-reduction line, Deng et al. 2021) on the
paper's logistic mixture — seeded, with an explicit wire-bits budget, so a
regression in either the floor or the communication cost fails loudly:

(a) **SLAQ-VR hits the deterministic floor** — with ``grad_mode="svrg"``
    the corrected gradients converge to the full local gradients, the
    eq.-7a criterion's variance floor vanishes, and the run lands within
    tolerance of the *deterministic* LAQ loss floor (plain SLAQ plateaus a
    multiple above it).
(b) **WK2 skips at least as much as WK** — the same-sample rule's LHS drops
    the (conservative) variance correction, so at matched thresholds it
    uploads at most as often; under high minibatch variance, far less.
(c) **1/t drives the SLAQ floor below the constant-stepsize plateau** —
    the stochastic plateau is proportional to ``alpha sigma^2``; the
    ``inv_t`` schedule shrinks it while the criterion stays consistent
    (``eta_at`` feeds both the update and the 1/(alpha^2 M^2) term).
(d) **partial participation scales uploads by ~p** — under client sampling
    (``StrategyConfig.participation="bernoulli"``, PR-5 round engine) a
    communication-rich LAQ run at p=0.5 still reaches the seeded loss
    target, with roughly half the uploads of full participation
    (``benchmarks/participation_frontier.py`` maps the whole frontier).

Plus the RNG-discipline regressions behind every frontier comparison:
same seed => bit-identical trajectory, and the batch stream is kind-stable
(spelling the same method as a ``kind`` alias or via ``lazy_rule`` cannot
perturb it).

CI runs this file as its own ``contracts`` job (`pytest -m contracts`,
slow-marked members included); the tier-1 job keeps deselecting ``slow``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, EtaSchedule, StrategyConfig,
                        run_gradient_based, run_stochastic)
from repro.data import classification_dataset, split_workers

M = 10
BITS = 3
ALPHA = 0.5
SEED = 1
CRIT = CriterionConfig(D=10, xi=0.08, t_bar=100)

pytestmark = pytest.mark.contracts


def logistic_setup(n_per_class=30, seed=0):
    X, Y = classification_dataset(jax.random.PRNGKey(seed),
                                  n_per_class=n_per_class)
    workers = split_workers(X, Y, M)
    N = X.shape[0]

    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * 0.01 * jnp.sum(params["w"] ** 2)) / N

    return loss_fn, {"w": jnp.zeros((10, 784))}, workers


def run(kind, cfg, *, steps, batch):
    loss_fn, p0, workers = logistic_setup()
    return run_stochastic(loss_fn, p0, workers, kind, steps=steps,
                          alpha=ALPHA, batch=batch, bits=BITS, seed=SEED,
                          laq_cfg=cfg)


def tail_loss(result, n=30):
    """Mean loss over the last ``n`` rounds — the plateau estimate (a
    single final sample would make the contract a noise lottery)."""
    return float(np.mean(np.asarray(result.loss)[-n:]))


BASE = StrategyConfig(kind="laq", bits=BITS, criterion=CRIT)


# ---------------------------------------------------------------------------
# (a) SLAQ-VR reaches the deterministic-LAQ floor.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("svrg_period", (10, 20))
def test_slaq_vr_reaches_deterministic_laq_floor(svrg_period):
    loss_fn, p0, workers = logistic_setup()
    det = run_gradient_based(loss_fn, p0, workers, BASE, steps=300,
                             alpha=ALPHA)
    det_floor = float(det.loss[-1])

    vr = run("slaq", BASE._replace(grad_mode="svrg",
                                   svrg_period=svrg_period),
             steps=300, batch=10)
    plain = run("slaq", BASE, steps=300, batch=10)

    # within 25% of the deterministic floor (measured ~8%)...
    assert tail_loss(vr) <= 1.25 * det_floor, (tail_loss(vr), det_floor)
    # ... which plain SLAQ provably is NOT: its variance plateau sits a
    # multiple above (measured ~6.5x) — the gap the correction closes
    assert tail_loss(plain) >= 2.0 * det_floor, (tail_loss(plain), det_floor)
    # bits budget: variance reduction must not buy the floor with uploads
    # (measured 9.4e5 — the deterministic-LAQ cost itself)
    assert float(vr.cum_bits[-1]) <= 1.5e6, float(vr.cum_bits[-1])


# ---------------------------------------------------------------------------
# (b) WK2 skips at least as much as WK at matched thresholds.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", (5, 10))
def test_wk2_skips_at_least_as_much_as_wk(batch):
    steps = 200
    rwk = run("slaq_wk", BASE, steps=steps, batch=batch)
    rwk2 = run("slaq_wk2", BASE, steps=steps, batch=batch)
    up_wk, up_wk2 = int(rwk.cum_uploads[-1]), int(rwk2.cum_uploads[-1])
    # the noise-free criterion can only enlarge the skip region; under high
    # minibatch variance the gap is an order of magnitude (measured
    # 29 vs 486 at batch=5)
    assert up_wk2 <= up_wk, (up_wk2, up_wk)
    if batch == 5:
        assert up_wk2 <= 0.5 * up_wk, (up_wk2, up_wk)
    # bits budgets (seeded; measured 6.8e5 / 1.1e7 at batch=5 and 2.6e7 at
    # batch=10 for WK — still ~20x under the dense-SGD cost)
    assert float(rwk2.cum_bits[-1]) <= 2.0e6, float(rwk2.cum_bits[-1])
    assert float(rwk.cum_bits[-1]) <= 4.0e7, float(rwk.cum_bits[-1])


# ---------------------------------------------------------------------------
# (c) 1/t schedule beats the constant-stepsize plateau.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_inv_t_schedule_beats_constant_plateau():
    const = run("slaq", BASE, steps=300, batch=10)
    invt = run("slaq", BASE._replace(
        eta_schedule=EtaSchedule(kind="inv_t", t0=50.0)), steps=300, batch=10)
    # the decreasing stepsize must land well below the constant plateau
    # (measured 0.067 vs 0.183 — a 2.7x gap; 0.7 leaves seed headroom)
    assert tail_loss(invt) < 0.7 * tail_loss(const), \
        (tail_loss(invt), tail_loss(const))
    # same skip machinery, same budget class (measured 8.7e5)
    assert float(invt.cum_bits[-1]) <= 1.5e6, float(invt.cum_bits[-1])


def test_halving_schedule_also_beats_constant():
    const = run("slaq", BASE, steps=200, batch=10)
    halv = run("slaq", BASE._replace(
        eta_schedule=EtaSchedule(kind="halving", halve_every=60)),
        steps=200, batch=10)
    assert tail_loss(halv) < tail_loss(const), \
        (tail_loss(halv), tail_loss(const))
    assert float(halv.cum_bits[-1]) <= 1.5e6, float(halv.cum_bits[-1])


# ---------------------------------------------------------------------------
# (d) Partial participation: p=0.5 LAQ reaches the target with ~p-scaled
#     uploads (communication-rich criterion, where sampling prunes upload
#     opportunities directly; with the paper criterion the skip rule
#     absorbs sampling — the frontier benchmark shows both regimes).
# ---------------------------------------------------------------------------

def test_partial_participation_half_uploads_reaches_target():
    loss_fn, p0, workers = logistic_setup()
    rich = StrategyConfig(kind="laq", bits=4,
                          criterion=CriterionConfig(D=10, xi=0.008, t_bar=100))
    full = run_gradient_based(loss_fn, p0, workers, rich, steps=300,
                              alpha=2.0)
    half = run_gradient_based(
        loss_fn, p0, workers,
        rich._replace(participation="bernoulli", participation_p=0.5),
        steps=300, alpha=2.0)
    target = 1.05 * float(full.loss[-1])
    assert float(half.loss[-1]) <= target, (float(half.loss[-1]), target)
    ratio = int(half.cum_uploads[-1]) / int(full.cum_uploads[-1])
    # seeded; measured 68/121 = 0.56 — "roughly half", with headroom for
    # cohort-draw variation if the availability stream ever changes
    assert 0.35 <= ratio <= 0.7, (int(half.cum_uploads[-1]),
                                  int(full.cum_uploads[-1]))


# ---------------------------------------------------------------------------
# RNG discipline: the regressions behind every frontier comparison.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,cfg", [
    ("slaq", BASE),
    ("slaq_wk2", BASE),
    ("slaq", BASE._replace(grad_mode="svrg", svrg_period=10)),
    ("qsgd", None),
])
def test_same_seed_bit_identical_trajectory(kind, cfg):
    """Determinism regression (satellite fix): minibatch keys derive
    functionally from (seed, stream, round, worker), so rerunning is
    bitwise reproducible — including the svrg anchor refresh and the
    compressor draws."""
    r1 = run(kind, cfg, steps=60, batch=5)
    r2 = run(kind, cfg, steps=60, batch=5)
    np.testing.assert_array_equal(np.asarray(r1.loss), np.asarray(r2.loss))
    np.testing.assert_array_equal(np.asarray(r1.cum_bits),
                                  np.asarray(r2.cum_bits))
    np.testing.assert_array_equal(np.asarray(r1.params["w"]),
                                  np.asarray(r2.params["w"]))


def test_batch_stream_is_kind_stable():
    """The same method spelled two ways — ``kind="slaq_wk"`` vs
    ``kind="slaq"`` + ``lazy_rule="lasg_wk"`` — must produce bit-identical
    trajectories: the kind dispatch cannot perturb the batch stream."""
    r_alias = run("slaq_wk", BASE, steps=60, batch=5)
    r_rule = run("slaq", BASE._replace(lazy_rule="lasg_wk"), steps=60,
                 batch=5)
    np.testing.assert_array_equal(np.asarray(r_alias.loss),
                                  np.asarray(r_rule.loss))
    np.testing.assert_array_equal(np.asarray(r_alias.cum_uploads),
                                  np.asarray(r_rule.cum_uploads))


def test_baseline_stream_independent_of_laq_cfg():
    """Baselines draw their batches from the shared stream regardless of
    the (ignored) LAQ knobs in ``laq_cfg``: an SGD run is bit-identical
    whether or not a quantized config rides along."""
    r_bare = run("sgd", None, steps=60, batch=5)
    r_cfg = run("sgd", BASE._replace(bits=8, per_leaf_radius=True),
                steps=60, batch=5)
    np.testing.assert_array_equal(np.asarray(r_bare.loss),
                                  np.asarray(r_cfg.loss))
    np.testing.assert_array_equal(np.asarray(r_bare.params["w"]),
                                  np.asarray(r_cfg.params["w"]))


# ---------------------------------------------------------------------------
# (e) EF-LAQ beats plain LAQ at 2 bits (benchmarks/ef_frontier.py headline,
#     pinned seeded): at b in {1, 2} the dense zero-less grid is too coarse
#     — plain LAQ plateaus orders of magnitude above the dense-b4 floor —
#     while the EF pipeline (top-k sparsify -> sign-magnitude quantize,
#     damped error memory) reaches it, in fewer total bits than the b=4
#     dense fallback.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", (1, 2))
def test_ef_laq_beats_plain_at_low_bits(bits):
    loss_fn, p0, workers = logistic_setup()
    crit = CriterionConfig(D=10, xi=0.08, t_bar=100)
    dense4 = StrategyConfig(kind="laq", bits=4, criterion=crit)
    plain = dense4._replace(bits=bits)
    ef = plain._replace(compressor="topk", compressor_k=0.025,
                        error_feedback=True)
    steps, alpha = 250, 2.0

    r4 = run_gradient_based(loss_fn, p0, workers, dense4, steps=steps,
                            alpha=alpha)
    rp = run_gradient_based(loss_fn, p0, workers, plain, steps=steps,
                            alpha=alpha)
    re = run_gradient_based(loss_fn, p0, workers, ef, steps=steps,
                            alpha=alpha)
    floor = tail_loss(r4)

    # EF reaches the dense-b4 floor (measured 1.27x at b=2, 1.25x at b=1)
    assert tail_loss(re) <= 1.6 * floor, (tail_loss(re), floor)
    # ... which plain LAQ at the same width provably does NOT (measured
    # ~250x at b=2, worse at b=1)
    assert tail_loss(rp) >= 10.0 * floor, (tail_loss(rp), floor)
    # and in fewer total wire bits than the dense-b4 fallback (measured
    # 1.15e6 vs 1.57e6 at b=2)
    assert float(re.cum_bits[-1]) < float(r4.cum_bits[-1]), \
        (float(re.cum_bits[-1]), float(r4.cum_bits[-1]))
    # seeded absolute budget so a laziness regression fails loudly even if
    # the dense baseline drifts with it
    assert float(re.cum_bits[-1]) <= 2.0e6, float(re.cum_bits[-1])


# ---------------------------------------------------------------------------
# (g) LM workload: LAQ trains the tiny transformer to the QGD floor at
#     strictly fewer wire bits (benchmarks/lm_frontier.py headline, pinned
#     seeded and deterministic — the AccumulatingSource fold streams each
#     worker's corpus through microbatches, so this is the exact
#     full-gradient LAQ of the paper on a real next-token objective).
# ---------------------------------------------------------------------------

def _first_reach_bits(result, target):
    """Bits at the first *sustained* crossing of ``target`` (trailing max
    never rises above it again) — a single lucky dip doesn't count."""
    loss = np.asarray(result.loss)
    trailing = np.maximum.accumulate(loss[::-1])[::-1]
    ks = np.nonzero(trailing <= target)[0]
    return None if ks.size == 0 else float(np.asarray(result.cum_bits)[ks[0]])


def test_lm_laq_reaches_qgd_floor_at_fewer_bits():
    from repro.core import RoundEngine
    from repro.core.engine import AccumulatingSource
    from repro.data import lm_worker_corpus
    from repro.models import init_params, lm_worker_loss
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="lm-micro", arch_type="dense", n_layers=2,
                      d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                      head_dim=16, d_ff=64, q_chunk=16, kv_chunk=8,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    W, steps = 4, 60
    corpus = lm_worker_corpus(0, W, 16, 16, cfg.vocab)
    loss_fn = lm_worker_loss(cfg, W)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def source():
        return AccumulatingSource(loss_fn, corpus, deterministic=True,
                                  accum=2, scale=1.0)

    def engine_run(kind):
        # b=8 on the LM: at b=4 the per-leaf quantization error inflates
        # the RHS of (7a) until every round skips and the run diverges —
        # the bit-width floor is itself workload-dependent
        strat = StrategyConfig(kind=kind, bits=8, per_leaf_radius=True,
                               criterion=CRIT)
        return RoundEngine(source(), strat, alpha=ALPHA).run(params, steps)

    qgd = engine_run("qgd")
    laq = engine_run("laq")
    floor = float(np.mean(np.asarray(qgd.loss)[-5:]))
    target = 1.05 * floor
    bits_qgd = _first_reach_bits(qgd, target)
    bits_laq = _first_reach_bits(laq, target)
    assert bits_qgd is not None and bits_laq is not None, (bits_laq, bits_qgd)
    # headline: strictly fewer bits to the same perplexity floor, with
    # seeded headroom (measured 1.36e7 vs 2.62e7 — a 0.52x ratio)
    assert bits_laq < 0.75 * bits_qgd, (bits_laq, bits_qgd)
    # the lazy run actually skips, and stays at the floor
    assert int(laq.cum_uploads[-1]) < W * steps, int(laq.cum_uploads[-1])
    assert float(laq.loss[-1]) <= 1.10 * floor, (float(laq.loss[-1]), floor)


# ---------------------------------------------------------------------------
# (f) Fault tolerance: defended LAQ survives payload corruption.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("reference", "fused"))
def test_defended_laq_survives_corruption(backend):
    """The PR-7 robustness contract (benchmarks/fault_frontier.py maps the
    full frontier): at >=10% per-worker per-round Inf payload corruption,
    upload validation keeps the run finite and lands it at the clean
    floor, while the undefended run's aggregate goes non-finite — on both
    wire backends (wire content is bit-identical by the core/wire.py
    contract, so the defense decisions must agree)."""
    from repro.core import DefenseConfig, FaultConfig
    loss_fn, p0, workers = logistic_setup()
    cfg = StrategyConfig(kind="laq", bits=4, criterion=CRIT,
                         wire_backend=backend)
    fc = FaultConfig(corrupt_p=0.1, corrupt_kind="inf", fault_seed=SEED)
    steps = 80

    clean = run_gradient_based(loss_fn, p0, workers, cfg, steps=steps,
                               alpha=ALPHA)
    undef = run_gradient_based(loss_fn, p0, workers, cfg._replace(faults=fc),
                               steps=steps, alpha=ALPHA)
    defended = run_gradient_based(
        loss_fn, p0, workers,
        cfg._replace(faults=fc, defense=DefenseConfig(validate=True)),
        steps=steps, alpha=ALPHA)

    assert not np.all(np.isfinite(np.asarray(undef.loss)))
    dl = np.asarray(defended.loss)
    assert np.all(np.isfinite(dl))
    assert tail_loss(defended, 10) < 1.10 * tail_loss(clean, 10)
    # honest accounting: rejected transmissions still pay their bits (the
    # corruption tax is real, and large under this lazy criterion), but the
    # defense itself adds no communication on top of the faulty run
    assert float(defended.cum_bits[-1]) <= float(undef.cum_bits[-1])
