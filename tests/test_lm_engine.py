"""Accumulation-exactness tier for the LM gradient source.

Pins the two contracts that let the LAQ engine train language models by
gradient accumulation (core/engine.py AccumulatingSource +
accumulate_loss_grads):

* **microbatch-vs-full parity** — the accumulated gradient over N
  microbatches equals MinibatchSource's single-backprop gradient on the
  concatenated batch: bit-identical at ``accum=1`` (the fold degenerates to
  the direct evaluation, same special case the sharded step takes), and to
  f32 reduction order (pinned-ulp, asserted <= 1e-6 absolute here) at
  ``accum in {2, 4}`` — the fold reassociates the mean, nothing else.
  At ``accum=1`` whole engine trajectories (params, uploads, bits) are
  bitwise interchangeable between the two sources on BOTH wire backends;
  the loss *record* differs by the chunked global-loss reduction order
  only.

* **trajectory golden** — a seeded 30-round tiny-transformer SLAQ run is
  bitwise deterministic (same seed -> identical losses/params), actually
  skips (skip rate > 0), learns (final loss < initial), and reproduces
  bitwise across the reference and fused wire backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RoundEngine, StrategyConfig
from repro.core.engine import (AccumulatingSource, FullBatchSource,
                               MinibatchSource)
from repro.data import lm_worker_corpus
from repro.models import init_params, lm_worker_loss
from repro.models.config import ModelConfig

CFG = ModelConfig(name="lm-micro", arch_type="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                  q_chunk=16, kv_chunk=8,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
W, N_LOCAL, SEQ = 4, 16, 16
BATCH = 8
SLAQ = StrategyConfig(kind="laq", bits=4, per_leaf_radius=True,
                      lazy_rule="lasg_wk")


@pytest.fixture(scope="module")
def setup():
    corpus = lm_worker_corpus(0, W, N_LOCAL, SEQ, CFG.vocab)
    loss_fn = lm_worker_loss(CFG, W)
    params = init_params(jax.random.PRNGKey(0), CFG)
    return corpus, loss_fn, params


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tree_maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("accum", [1, 2, 4])
def test_accum_gradient_parity(setup, accum):
    """Accumulated gradient == single-backprop gradient on the same batch:
    bitwise at accum=1, <= 1e-6 abs (f32 reduction order) above."""
    corpus, loss_fn, params = setup
    mb = MinibatchSource(loss_fn, corpus, batch=BATCH, seed=0)
    acc = AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0, accum=accum)
    bm, ba = mb.sample(3), acc.sample(3)
    # the sampler draws the SAME index vector and just reshapes it
    assert np.array_equal(
        np.asarray(bm["tokens"]),
        np.asarray(ba["tokens"]).reshape(W, BATCH, SEQ))
    gm = mb.eval_at(params, None, bm)
    ga = acc.eval_at(params, None, ba)
    if accum == 1:
        assert _tree_equal(gm, ga)
    else:
        assert _tree_maxdiff(gm, ga) <= 1e-6


def test_per_device_knob(setup):
    """per_device is the levanter-style parallelism knob: accum derives
    from it, and the sampled examples are unchanged."""
    corpus, loss_fn, _ = setup
    src = AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0,
                             per_device=2)
    assert src.accum == BATCH // 2 and src.micro == 2
    ref = AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0,
                             accum=BATCH // 2)
    assert _tree_equal(src.sample(0), ref.sample(0))


def test_deterministic_mode_matches_fullbatch(setup):
    """deterministic=True streams the whole corpus through the fold: the
    FullBatchSource gradient at the accumulation memory profile."""
    corpus, loss_fn, params = setup
    det = AccumulatingSource(loss_fn, corpus, deterministic=True, accum=2,
                             scale=1.0)
    assert not det.stochastic
    fb = FullBatchSource(loss_fn, corpus)
    gd = det.eval_at(params, None, det.sample(0))
    gf = fb.eval_at(params, None, None)
    assert _tree_maxdiff(gd, gf) <= 1e-6
    np.testing.assert_allclose(float(det.global_loss(params)),
                               float(fb.global_loss(params)), rtol=1e-6)


@pytest.mark.parametrize("wire_backend", ["reference", "fused"])
def test_accum1_trajectory_interchangeable(setup, wire_backend):
    """At accum=1 the engine cannot tell the sources apart: params,
    uploads and bits trajectories are bitwise equal on both backends."""
    corpus, loss_fn, params = setup
    cfg = SLAQ._replace(wire_backend=wire_backend)
    ra = RoundEngine(AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0,
                                        accum=1), cfg, alpha=0.5).run(params, 8)
    rm = RoundEngine(MinibatchSource(loss_fn, corpus, batch=BATCH, seed=0),
                     cfg, alpha=0.5).run(params, 8)
    assert _tree_equal(ra.params, rm.params)
    assert np.array_equal(np.asarray(ra.cum_uploads), np.asarray(rm.cum_uploads))
    assert np.array_equal(np.asarray(ra.cum_bits), np.asarray(rm.cum_bits))
    # the loss record is a diagnostic: chunked vs single-shot reduction
    np.testing.assert_allclose(np.asarray(ra.loss), np.asarray(rm.loss),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("wire_backend", ["reference", "fused"])
def test_lm_trajectory_golden(setup, wire_backend):
    """Seeded 30-round tiny-transformer SLAQ run: bitwise same-seed
    determinism, skip rate > 0, and it learns."""
    corpus, loss_fn, params = setup
    cfg = SLAQ._replace(wire_backend=wire_backend)

    def run():
        src = AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0,
                                 accum=2, scale=1.0)
        return RoundEngine(src, cfg, alpha=0.5).run(params, 30)

    r1, r2 = run(), run()
    assert np.array_equal(np.asarray(r1.loss), np.asarray(r2.loss))
    assert _tree_equal(r1.params, r2.params)
    assert bool(np.isfinite(np.asarray(r1.loss)).all())
    assert float(r1.loss[-1]) < float(r1.loss[0])
    uploads = int(r1.cum_uploads[-1])
    assert 0 < uploads < W * 30, f"no skips: {uploads}/{W * 30}"


def test_trajectory_golden_backends_bitwise(setup):
    """The wire-content contract (core/wire.py) extends to the whole LM
    trajectory: reference and fused backends reproduce identical runs."""
    corpus, loss_fn, params = setup
    losses = {}
    for wb in ("reference", "fused"):
        src = AccumulatingSource(loss_fn, corpus, batch=BATCH, seed=0,
                                 accum=2, scale=1.0)
        losses[wb] = RoundEngine(src, SLAQ._replace(wire_backend=wb),
                                 alpha=0.5).run(params, 30)
    assert np.array_equal(np.asarray(losses["reference"].loss),
                          np.asarray(losses["fused"].loss))
    assert np.array_equal(np.asarray(losses["reference"].cum_bits),
                          np.asarray(losses["fused"].cum_bits))
