"""Adaptive bit-width (A-LAQ) tests: controller invariants, dynamic-quantizer
bit-exactness against the fixed path, 2-bit pack/unpack roundtrip, and the
bits-to-loss win of adaptive over fixed-4-bit on a quadratic problem."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitSchedule, CriterionConfig, StrategyConfig,
                        adaptive_roundtrip, grid_costs, pack_codes,
                        quantize_roundtrip, run_gradient_based, select_bits,
                        unpack_codes, upload_bits)

GRID = (2, 4, 8)


def quadratic_problem(M=10, p=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kc, ka = jax.random.split(key)
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M
    return loss_fn, {"x": jnp.zeros((p,))}, (centers, scales)


def _run(cfg, steps=400, alpha=0.3):
    loss_fn, p0, data = quadratic_problem()
    return run_gradient_based(loss_fn, p0, data, cfg, steps=steps, alpha=alpha)


CRIT = CriterionConfig(D=10, xi=0.08, t_bar=100)


# ---------------------------------------------------------------------------
# Exactness: constant schedule == fixed-bit LAQ; pinned dynamic == fixed.
# ---------------------------------------------------------------------------

def test_constant_schedule_matches_fixed_exactly():
    """A constant schedule must reproduce fixed-bit LAQ bit-for-bit —
    trajectories, uploads AND wire-bit accounting."""
    fixed = _run(StrategyConfig(kind="laq", bits=4, criterion=CRIT))
    const = _run(StrategyConfig(kind="laq", bits=6, criterion=CRIT,
                                bit_schedule=BitSchedule(kind="constant", bits=4)))
    np.testing.assert_array_equal(np.asarray(fixed.loss), np.asarray(const.loss))
    np.testing.assert_array_equal(np.asarray(fixed.cum_bits),
                                  np.asarray(const.cum_bits))
    np.testing.assert_array_equal(np.asarray(fixed.cum_uploads),
                                  np.asarray(const.cum_uploads))


@pytest.mark.parametrize("bits", GRID)
def test_pinned_dynamic_quantizer_bit_exact(bits):
    """The masked-select dynamic quantizer pinned to one width must equal the
    static quantizer bit-for-bit (codes, delta, error)."""
    key = jax.random.PRNGKey(bits)
    g = {"a": jax.random.normal(key, (64,)) * 3,
         "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 16))}
    qh = jax.tree.map(lambda x: 0.3 * x, g)
    onehot = jnp.zeros((len(GRID),)).at[GRID.index(bits)].set(1.0)
    qn_d, d_d, R_d, e_d = adaptive_roundtrip(g, qh, GRID, onehot)
    qn_s, d_s, R_s, e_s = quantize_roundtrip(g, qh, bits)
    for x, y in zip(jax.tree.leaves(d_d), jax.tree.leaves(d_s)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert float(e_d) == float(e_s)
    assert float(R_d) == float(R_s)


# ---------------------------------------------------------------------------
# Controller invariants.
# ---------------------------------------------------------------------------

@hypothesis.given(spent=st.floats(0.0, 1e7), step=st.integers(0, 500),
                  R=st.floats(0.0, 10.0))
@hypothesis.settings(max_examples=60, deadline=None)
def test_property_budget_controller_respects_budget(spent, step, R):
    """Whenever the burst-extended allowance (pro-rata + one max-width
    upload) covers at least the smallest width, the chosen upload must fit
    it; the choice is always on the grid."""
    p = 1000
    sched = BitSchedule(kind="budget", grid=GRID, thresholds=(0.05, 0.5),
                        total_bits=4.0 * p * 200, horizon=200).validate()
    b, onehot, _ = select_bits(sched, jnp.float32(R), jnp.float32(spent),
                               jnp.int32(step), p)
    b = float(b)
    assert b in GRID
    assert float(jnp.sum(onehot)) == 1.0
    costs = np.asarray(grid_costs(sched, p))
    rate = sched.total_bits / sched.horizon
    allowance = rate * (step + 1) + costs[-1] - spent
    chosen_cost = float(upload_bits(p, b, bit_sidecar=True))
    if allowance >= costs[0]:
        assert chosen_cost <= allowance + 1e-3
    else:
        assert b == min(GRID)


@hypothesis.given(R=st.floats(0.0, 10.0))
@hypothesis.settings(max_examples=40, deadline=None)
def test_property_radius_schedule_monotone(R):
    """More innovation radius never buys fewer bits."""
    sched = BitSchedule(kind="radius", grid=GRID, thresholds=(0.05, 0.5)).validate()
    b_lo, _, _ = select_bits(sched, jnp.float32(R), jnp.float32(0), jnp.int32(0), 100)
    b_hi, _, _ = select_bits(sched, jnp.float32(R * 2 + 1e-3), jnp.float32(0),
                             jnp.int32(0), 100)
    assert float(b_hi) >= float(b_lo)
    assert float(b_lo) in GRID


def test_budget_run_tracks_rate():
    """End-to-end: with a tight budget the controller keeps cumulative spend
    within one max-width upload of the pro-rata allowance, every round."""
    p = 20
    steps = 150
    budget = 3.0 * p * steps          # ~3 bits/coord/round per worker
    sched = BitSchedule(kind="budget", grid=GRID, thresholds=(1e-4, 1e-3),
                        total_bits=budget, horizon=steps)
    r = _run(StrategyConfig(kind="laq", criterion=CRIT, bit_schedule=sched),
             steps=steps)
    rate = budget / steps
    per_round_cap = float(upload_bits(p, max(GRID), bit_sidecar=True))
    cum = np.asarray(r.cum_bits) / 10          # per worker (M=10)
    ks = np.arange(1, steps + 1)
    assert np.all(cum <= rate * ks + per_round_cap + 1e-3)
    assert np.isfinite(float(r.loss[-1]))


# ---------------------------------------------------------------------------
# Scale-free ("rel") thresholds: fractions of the bootstrap-anchored radius.
# ---------------------------------------------------------------------------

def test_rel_mode_bootstrap_selects_max_width():
    """With no anchor yet, the anchor snaps to R itself, so any positive R
    exceeds every fractional threshold -> the dense bootstrap quantizes at
    the top of the grid, whatever the problem's radius scale."""
    sched = BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                        thresholds=(0.01, 0.1))
    for R in (1e-6, 1.0, 1e6):
        b, _, anchor = select_bits(sched, jnp.float32(R), jnp.float32(0),
                                   jnp.int32(0), 100)
        assert float(b) == max(GRID)
        assert float(anchor) == np.float32(R)


def test_rel_mode_width_steps_down_with_decaying_radius():
    """Against a frozen anchor, the width follows R/anchor through the
    fractions; the running-max anchor never decreases (anchor_decay=1)."""
    sched = BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                        thresholds=(0.01, 0.1))
    anchor = jnp.float32(0.0)
    widths = []
    for R in (8.0, 2.0, 0.5, 0.05, 0.05e-1):
        b, _, anchor = select_bits(sched, jnp.float32(R), jnp.float32(0),
                                   jnp.int32(0), 100, R_anchor=anchor)
        widths.append(float(b))
    assert float(anchor) == 8.0          # running max = bootstrap radius
    assert widths[0] == max(GRID)
    assert widths == sorted(widths, reverse=True)
    assert widths[-1] == min(GRID)


def test_rel_mode_fraction_above_one_picks_bootstrap_width():
    """Fractions >= 1 mark levels unreachable after the bootstrap, and at
    the bootstrap round (R == anchor) exactly the fractions < 1 are
    exceeded: (0.5, 2.0) bootstraps at the middle of the grid and never
    buys the top."""
    sched = BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                        thresholds=(0.5, 2.0))
    b, _, anchor = select_bits(sched, jnp.float32(3.0), jnp.float32(0),
                               jnp.int32(0), 100)          # bootstrap
    assert float(b) == 4
    for R in (2.9, 1.51, 1.0, 0.1):                        # post-bootstrap
        b, _, anchor = select_bits(sched, jnp.float32(R), jnp.float32(0),
                                   jnp.int32(0), 100, R_anchor=anchor)
        assert float(b) == (4 if R > 1.5 else 2)


def test_rel_mode_validate_rejects_bad_schedules():
    with pytest.raises(AssertionError):
        BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                    thresholds=(0.5, 0.1)).validate()      # not ascending
    with pytest.raises(AssertionError):
        BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                    thresholds=(0.01, 0.1), anchor_decay=1.5).validate()
    with pytest.raises(AssertionError):
        BitSchedule(kind="radius", grid=GRID, threshold_mode="oops",
                    thresholds=(0.01, 0.1)).validate()


def test_rel_mode_beats_fixed_bits_to_loss_without_tuning():
    """The headline scale-free claim: generic fractions (no per-problem
    radii) reach the fixed-4-bit loss with fewer cumulative wire bits."""
    fixed = _run(StrategyConfig(kind="laq", bits=4, criterion=CRIT))
    sched = BitSchedule(kind="radius", grid=GRID, threshold_mode="rel",
                        thresholds=(0.01, 0.1))
    ad = _run(StrategyConfig(kind="laq", criterion=CRIT, bit_schedule=sched))
    target = float(fixed.loss[-1]) + 1e-4
    reached = np.asarray(ad.loss) <= target
    assert reached.any(), (float(ad.loss[-1]), target)
    k = int(np.argmax(reached))
    assert float(ad.cum_bits[k]) < float(fixed.cum_bits[-1])


# ---------------------------------------------------------------------------
# 2-bit wire format.
# ---------------------------------------------------------------------------

@hypothesis.given(n4=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=40, deadline=None)
def test_property_2bit_pack_unpack_roundtrip(n4, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (4 * n4,), 0, 4,
                               dtype=jnp.int32).astype(jnp.uint8)
    packed = pack_codes(codes, 2)
    assert packed.nbytes == codes.size // 4
    out = unpack_codes(packed, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@pytest.mark.parametrize("bits", GRID)
def test_pack_codes_matches_wire_cost(bits):
    p = 240
    codes = jnp.arange(p, dtype=jnp.int32).astype(jnp.uint8) % (2 ** bits)
    packed = pack_codes(codes, bits)
    assert packed.nbytes * 8 == bits * p
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, bits)),
                                  np.asarray(codes))


# ---------------------------------------------------------------------------
# The A-LAQ claim: better bits-to-loss than fixed 4-bit.
# ---------------------------------------------------------------------------

def test_adaptive_beats_fixed_bits_to_loss():
    """Radius-decay adaptive LAQ reaches the fixed-4-bit final loss with
    fewer cumulative wire bits (paper Fig. 3 decay made actionable)."""
    fixed = _run(StrategyConfig(kind="laq", bits=4, criterion=CRIT))
    sched = BitSchedule(kind="radius", grid=GRID, thresholds=(0.05, 0.5))
    ad = _run(StrategyConfig(kind="laq", criterion=CRIT, bit_schedule=sched))
    target = float(fixed.loss[-1]) + 1e-4
    reached = np.asarray(ad.loss) <= target
    assert reached.any(), (float(ad.loss[-1]), target)
    k = int(np.argmax(reached))
    assert float(ad.cum_bits[k]) < float(fixed.cum_bits[-1]), \
        (float(ad.cum_bits[k]), float(fixed.cum_bits[-1]))
