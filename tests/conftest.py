"""Shared test config.

Provides a minimal, deterministic fallback implementation of the `hypothesis`
API surface these tests use when the real package is unavailable (the
offline validation container has no network; CI installs the real thing via
``pip install -e .[test]``).  The fallback draws a fixed number of seeded
pseudo-random examples per test — strictly weaker than hypothesis (no
shrinking, no example database) but it keeps every property test collecting
and exercising the invariants everywhere.
"""
from __future__ import annotations

import sys
import types
import zlib


def _install_hypothesis_fallback():
    import numpy as np

    class Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise RuntimeError("filter predicate too strict")
            return Strategy(draw)

        def map(self, fn):
            return Strategy(lambda rng: fn(self._draw(rng)))

    def floats(min_value=None, max_value=None, width=64, **_):
        lo = -1e9 if min_value is None else float(min_value)
        hi = 1e9 if max_value is None else float(max_value)

        def draw(rng):
            # bias towards the endpoints (hypothesis probes corners first)
            r = rng.rand()
            if r < 0.05:
                v = lo
            elif r < 0.1:
                v = hi
            else:
                v = lo + (hi - lo) * rng.rand()
            if width == 32:
                v = float(np.float32(v))
                v = min(max(v, lo), hi)
            return v
        return Strategy(draw)

    def integers(min_value, max_value):
        def draw(rng):
            r = rng.rand()
            if r < 0.05:
                return int(min_value)
            if r < 0.1:
                return int(max_value)
            return int(rng.randint(min_value, max_value + 1))
        return Strategy(draw)

    def booleans():
        return Strategy(lambda rng: bool(rng.randint(0, 2)))

    def sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rng: seq[rng.randint(0, len(seq))])

    def tuples(*strats):
        return Strategy(lambda rng: tuple(_draw_any(s, rng) for s in strats))

    def just(v):
        return Strategy(lambda rng: v)

    def _draw_any(v, rng):
        return v.draw(rng) if isinstance(v, Strategy) else v

    def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
        def draw(rng):
            nd = rng.randint(min_dims, max_dims + 1)
            return tuple(int(rng.randint(min_side, max_side + 1))
                         for _ in range(nd))
        return Strategy(draw)

    def arrays(dtype, shape, elements=None, **_):
        def draw(rng):
            shp = _draw_any(shape, rng)
            if isinstance(shp, int):
                shp = (shp,)
            n = int(np.prod(shp)) if shp else 1
            if elements is None:
                flat = rng.rand(n)
            else:
                flat = np.array([_draw_any(elements, rng) for _ in range(n)])
            return flat.astype(dtype).reshape(shp)
        return Strategy(draw)

    def given(*gargs, **gkwargs):
        assert not gargs, "fallback @given supports keyword strategies only"

        def deco(fn):
            def wrapper(*args, **kwargs):
                # settings() may sit above or below given(): check both
                max_examples = getattr(
                    wrapper, "_fallback_max_examples",
                    getattr(fn, "_fallback_max_examples", 25))
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = np.random.RandomState(seed)
                for _ in range(max_examples):
                    drawn = {k: s.draw(rng) for k, s in gkwargs.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except _FallbackAssume:
                        continue          # rejected example, like hypothesis
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_):
        def deco(fn):
            # applied below @given (decorators run bottom-up): tag the raw fn
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda cond: None if cond else (_ for _ in ()).throw(
        _FallbackAssume())
    hyp.__is_repro_fallback__ = True

    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in (("floats", floats), ("integers", integers),
                      ("booleans", booleans), ("sampled_from", sampled_from),
                      ("tuples", tuples), ("just", just)):
        setattr(st_mod, name, obj)
    hyp.strategies = st_mod

    extra_mod = types.ModuleType("hypothesis.extra")
    hnp_mod = types.ModuleType("hypothesis.extra.numpy")
    hnp_mod.arrays = arrays
    hnp_mod.array_shapes = array_shapes
    extra_mod.numpy = hnp_mod
    hyp.extra = extra_mod

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra_mod
    sys.modules["hypothesis.extra.numpy"] = hnp_mod


class _FallbackAssume(Exception):
    pass


try:
    import hypothesis  # noqa: F401  (the real package, when installed)
except ImportError:
    _install_hypothesis_fallback()
