"""Participation/staleness scenarios of the round engine (core/engine.py).

Covers the mask semantics (unavailable == masked exactly like a lazy skip:
clocks grow, no wire bits, qhat and estimator state frozen), the
deterministic cohort draw shared by the simulated and sharded paths, the
bounded-delay staleness ring, and the composition with the LAQ skip rule,
the LASG rules and the dense baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, StrategyConfig, run_gradient_based,
                        run_stochastic)
from repro.core.engine import (DelayedParticipation, FullParticipation,
                               SampledParticipation, make_participation,
                               participation_mask)
from repro.core.strategy import aggregate, init_comm_state

# the engine-parity fixtures are the reference problems for engine-level
# tests — share them instead of growing another copy
from test_engine_parity import quadratic_problem
from test_engine_parity import regression_problem as stochastic_problem

CRIT = CriterionConfig(D=10, xi=0.08, t_bar=100)
LAQ = StrategyConfig(kind="laq", bits=4, criterion=CRIT)


# ---------------------------------------------------------------------------
# The mask function and the model factory.
# ---------------------------------------------------------------------------

def test_mask_modes_and_determinism():
    cfg = LAQ._replace(participation="bernoulli", participation_p=0.5)
    m1 = participation_mask(cfg, 7, 10)
    m2 = participation_mask(cfg, 7, 10)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert m1.shape == (10,) and m1.dtype == jnp.bool_
    # different rounds draw different cohorts (overwhelmingly)
    draws = np.stack([np.asarray(participation_mask(cfg, k, 10))
                      for k in range(50)])
    assert 0.3 < draws.mean() < 0.7          # p=0.5 frequency sanity
    assert len({tuple(d) for d in draws}) > 10

    # full / delay never mask
    assert participation_mask(LAQ, 0, 10) is None
    assert participation_mask(LAQ._replace(participation="delay",
                                           max_delay=4), 0, 10) is None


def test_fixed_k_mask_exact_cohort_size():
    cfg = LAQ._replace(participation="fixed_k", participation_p=0.3)
    for k in range(20):
        m = np.asarray(participation_mask(cfg, k, 10))
        assert m.sum() == 3, (k, m)


def test_mask_seed_independent_of_batch_stream():
    a = participation_mask(LAQ._replace(participation="bernoulli",
                                        participation_p=0.5,
                                        participation_seed=0), 3, 10)
    b = participation_mask(LAQ._replace(participation="bernoulli",
                                        participation_p=0.5,
                                        participation_seed=1), 3, 10)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_factory_normalizes_degenerate_knobs():
    assert isinstance(make_participation(LAQ, 10), FullParticipation)
    # delay with no delay, sampling with p>=1 == full participation
    assert isinstance(make_participation(
        LAQ._replace(participation="delay", max_delay=0), 10),
        FullParticipation)
    assert isinstance(make_participation(
        LAQ._replace(participation="bernoulli", participation_p=1.0), 10),
        FullParticipation)
    assert isinstance(make_participation(
        LAQ._replace(participation="fixed_k", participation_p=1.0), 10),
        FullParticipation)
    assert isinstance(make_participation(
        LAQ._replace(participation="bernoulli", participation_p=0.5), 10),
        SampledParticipation)
    assert isinstance(make_participation(
        LAQ._replace(participation="delay", max_delay=3), 10),
        DelayedParticipation)
    with pytest.raises(AssertionError):
        make_participation(LAQ._replace(participation="nope"), 10)


def test_delay_ring_serves_correct_iterates():
    part = DelayedParticipation(max_delay=2, n_workers=5)
    np.testing.assert_array_equal(np.asarray(part.delays), [0, 1, 2, 0, 1])
    hist = part.init({"x": jnp.zeros(())})
    # push iterates 1., 2., 3.: at round k worker m must see theta^{k-d_m},
    # clamped to theta^0 = 0 before enough history exists
    for k, expect in [(1.0, [1.0, 0.0, 0.0, 1.0, 0.0]),
                      (2.0, [2.0, 1.0, 0.0, 2.0, 1.0]),
                      (3.0, [3.0, 2.0, 1.0, 3.0, 2.0])]:
        avail, thetas, hist = part.begin_round(hist, 0, {"x": jnp.full((), k)})
        assert avail is None
        np.testing.assert_array_equal(np.asarray(thetas["x"]), expect)


# ---------------------------------------------------------------------------
# Masking semantics inside the state machine.
# ---------------------------------------------------------------------------

def test_unavailable_worker_masked_like_lazy_skip():
    """A masked worker contributes nothing to the aggregate or the bit
    accounting; its clock grows and its qhat / eps / anchor state freeze —
    exactly the lazy-skip footprint."""
    loss_fn, p0, data = quadratic_problem(M=4)
    grads = jax.vmap(lambda d: jax.grad(loss_fn)(p0, d))(data)
    cfg = LAQ
    st = init_comm_state(p0, 4, cfg)
    avail = jnp.array([True, False, True, False])
    agg, st1, metrics = aggregate(st, grads, 0.3, cfg, avail=avail)
    # bootstrap round: every AVAILABLE worker uploads (clocks start at
    # t_bar), the masked ones cannot
    assert int(metrics.uploads) == 2
    np.testing.assert_array_equal(np.asarray(st1.clocks),
                                  [0, CRIT.t_bar + 1, 0, CRIT.t_bar + 1])
    assert float(jnp.sum(st1.bits_spent[jnp.array([1, 3])])) == 0.0
    for leaf in jax.tree.leaves(st1.qhat):
        np.testing.assert_array_equal(np.asarray(leaf[1]),
                                      np.zeros_like(leaf[1]))
    # the overdue workers upload at their next available round
    agg, st2, metrics2 = aggregate(st1, grads, 0.3, cfg,
                                   avail=jnp.array([False, True, False, True]))
    assert int(metrics2.uploads) == 2
    np.testing.assert_array_equal(np.asarray(st2.clocks), [1, 0, 1, 0])


def test_full_participation_knobs_are_bitwise_noop():
    """participation='bernoulli' with p=1 (or delay with max_delay=0) must
    reproduce the default-config trajectory bitwise — the factory routes
    the degenerate knobs to FullParticipation."""
    loss_fn, p0, data = quadratic_problem()
    base = run_gradient_based(loss_fn, p0, data, LAQ, steps=40, alpha=0.3)
    for cfg in (LAQ._replace(participation="bernoulli", participation_p=1.0),
                LAQ._replace(participation="delay", max_delay=0)):
        r = run_gradient_based(loss_fn, p0, data, cfg, steps=40, alpha=0.3)
        np.testing.assert_array_equal(np.asarray(base.loss),
                                      np.asarray(r.loss))
        np.testing.assert_array_equal(np.asarray(base.cum_bits),
                                      np.asarray(r.cum_bits))
        np.testing.assert_array_equal(np.asarray(base.params["x"]),
                                      np.asarray(r.params["x"]))


def test_dense_methods_upload_exactly_the_cohort():
    """QGD never skips, so under sampling its per-round uploads equal the
    cohort size exactly — the sharpest accounting check."""
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(kind="qgd", participation="bernoulli",
                       participation_p=0.5)
    steps = 60
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=steps, alpha=0.3)
    per_round = np.diff(np.asarray(r.cum_uploads), prepend=0)
    cohorts = np.array([int(participation_mask(cfg, k, 10).sum())
                        for k in range(steps)])
    np.testing.assert_array_equal(per_round, cohorts)


def test_sampled_laq_converges_with_fewer_uploads():
    loss_fn, p0, data = quadratic_problem()
    full = run_gradient_based(loss_fn, p0, data, LAQ, steps=400, alpha=0.3)
    half = run_gradient_based(
        loss_fn, p0, data,
        LAQ._replace(participation="bernoulli", participation_p=0.5),
        steps=400, alpha=0.3)
    assert float(half.loss[-1]) < 1.02 * float(full.loss[-1])
    assert int(half.cum_uploads[-1]) <= int(full.cum_uploads[-1])
    assert float(half.grad_norm_sq[-1]) < 1e-4


def test_delayed_laq_converges():
    loss_fn, p0, data = quadratic_problem()
    r = run_gradient_based(
        loss_fn, p0, data, LAQ._replace(participation="delay", max_delay=4),
        steps=400, alpha=0.3)
    full = run_gradient_based(loss_fn, p0, data, LAQ, steps=400, alpha=0.3)
    assert float(r.loss[-1]) < 1.05 * float(full.loss[-1])
    assert float(r.grad_norm_sq[-1]) < 1e-3
    assert np.isfinite(np.asarray(r.loss)).all()


@pytest.mark.parametrize("kind", ["slaq", "slaq_wk", "slaq_wk2", "slaq_ps"])
def test_stochastic_rules_compose_with_sampling(kind):
    """Every LASG rule runs under client sampling: the estimator state of
    masked workers is held, the run stays finite and learns."""
    loss_fn, p0, data = stochastic_problem()
    cfg = StrategyConfig(kind="laq", bits=4,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=20),
                         participation="bernoulli", participation_p=0.6)
    r = run_stochastic(loss_fn, p0, data, kind, steps=120, alpha=0.3,
                       batch=4, bits=4, seed=2, laq_cfg=cfg)
    assert np.isfinite(np.asarray(r.loss)).all()
    assert float(r.loss[-1]) < 0.6 * float(r.loss[0])
    # sampling can only remove upload opportunities
    dense = 120 * 6
    assert int(r.cum_uploads[-1]) < dense


def test_baselines_compose_with_sampling():
    """sgd/qsgd under sampling upload exactly the cohort each round and
    scale their bits accordingly."""
    loss_fn, p0, data = stochastic_problem()
    cfg = StrategyConfig(participation="bernoulli", participation_p=0.5,
                         participation_seed=4)
    steps = 80
    r_full = run_stochastic(loss_fn, p0, data, "qsgd", steps=steps,
                            alpha=0.05, batch=4, bits=4, seed=2)
    r_half = run_stochastic(loss_fn, p0, data, "qsgd", steps=steps,
                            alpha=0.05, batch=4, bits=4, seed=2, laq_cfg=cfg)
    cohorts = np.array([int(participation_mask(cfg, k, 6).sum())
                        for k in range(steps)])
    per_round = np.diff(np.asarray(r_half.cum_uploads), prepend=0)
    np.testing.assert_array_equal(per_round, cohorts)
    ratio = float(r_half.cum_bits[-1]) / float(r_full.cum_bits[-1])
    assert abs(ratio - cohorts.sum() / (steps * 6)) < 1e-6
    assert np.isfinite(np.asarray(r_half.loss)).all()


def test_svrg_and_delay_compose():
    """Variance-reduced gradients under bounded staleness: the exotic
    corner (anchor correction evaluated at stale per-worker iterates)
    stays finite and learns."""
    loss_fn, p0, data = stochastic_problem()
    cfg = StrategyConfig(kind="laq", bits=4,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=20),
                         grad_mode="svrg", svrg_period=7,
                         participation="delay", max_delay=3)
    r = run_stochastic(loss_fn, p0, data, "slaq", steps=120, alpha=0.3,
                       batch=4, bits=4, seed=2, laq_cfg=cfg)
    assert np.isfinite(np.asarray(r.loss)).all()
    assert float(r.loss[-1]) < 0.6 * float(r.loss[0])


# ---------------------------------------------------------------------------
# Markov churn.
# ---------------------------------------------------------------------------

def _markov_trace(p, sojourn, rounds=4000, W=16, seed=0):
    from repro.core.engine import MarkovParticipation
    cfg = LAQ._replace(participation="markov", participation_p=p,
                       markov_sojourn=sojourn, participation_seed=seed)
    model = MarkovParticipation(cfg, W)
    on = model.init(None)
    rows = []
    for k in range(rounds):
        avail, _, on = model.begin_round(on, k, None)
        rows.append(np.asarray(avail))
    return np.stack(rows)                       # [rounds, W]


def test_markov_stationary_availability_matches_p():
    for p, sojourn in [(0.5, 8.0), (0.8, 4.0), (0.3, 10.0)]:
        trace = _markov_trace(p, sojourn, rounds=3000)
        assert abs(trace.mean() - p) < 0.05, (p, sojourn, trace.mean())


def test_markov_sojourn_controls_burstiness():
    """Mean ON-streak length ~= sojourn; the iid-equivalent setting
    (sojourn = 1/(1-p)) shows no serial correlation while a long sojourn
    shows strong positive correlation at matched mean availability."""
    def mean_streak(col):
        streaks, run = [], 0
        for v in col:
            if v:
                run += 1
            elif run:
                streaks.append(run)
                run = 0
        if run:
            streaks.append(run)
        return np.mean(streaks)

    p = 0.5
    bursty = _markov_trace(p, 8.0)
    iid = _markov_trace(p, 1.0 / (1.0 - p))
    streak_b = np.mean([mean_streak(bursty[:, m]) for m in range(16)])
    streak_i = np.mean([mean_streak(iid[:, m]) for m in range(16)])
    assert 6.0 < streak_b < 10.0, streak_b       # ~= sojourn 8
    assert 1.5 < streak_i < 2.5, streak_i        # ~= geometric(1-p) mean 2

    def serial_corr(tr):
        a, b = tr[:-1].ravel(), tr[1:].ravel()
        return np.corrcoef(a, b)[0, 1]

    assert serial_corr(bursty) > 0.5
    assert abs(serial_corr(iid)) < 0.1


def test_markov_deterministic_and_seeded():
    a = _markov_trace(0.5, 8.0, rounds=50, seed=0)
    np.testing.assert_array_equal(a, _markov_trace(0.5, 8.0, rounds=50,
                                                   seed=0))
    assert not np.array_equal(a, _markov_trace(0.5, 8.0, rounds=50, seed=1))


def test_markov_factory_and_stateless_mask_contract():
    from repro.core.engine import MarkovParticipation, make_participation
    cfg = LAQ._replace(participation="markov", participation_p=0.6)
    assert isinstance(make_participation(cfg, 10), MarkovParticipation)
    # p >= 1 degenerates to full participation
    assert isinstance(make_participation(
        cfg._replace(participation_p=1.0), 10), FullParticipation)
    # the stateless mask cannot express the carried chain: loud error
    with pytest.raises(ValueError, match="stateful"):
        participation_mask(cfg, 0, 10)


def test_markov_run_converges_and_accounts_bits():
    loss_fn, p0, data = quadratic_problem()
    cfg = LAQ._replace(participation="markov", participation_p=0.7,
                       markov_sojourn=6.0,
                       criterion=CriterionConfig(D=10, xi=0.08, t_bar=20))
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=200, alpha=0.3)
    assert float(r.grad_norm_sq[-1]) < 1e-3
    # an unavailable worker ships nothing: per-round uploads never exceed
    # the chain's deterministic availability trace (recomputed here)
    from repro.core.engine import MarkovParticipation
    model = MarkovParticipation(cfg, 10)
    on = model.init(None)
    cum = np.asarray(r.cum_uploads)
    per_round = np.diff(np.concatenate([[0.0], cum]))
    for k in range(200):
        avail, _, on = model.begin_round(on, k, None)
        assert per_round[k] <= int(np.asarray(avail).sum()), k
