"""Substrate tests: compressors (unbiasedness), optimizers, checkpoint, data."""
import os
import tempfile

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import qsgd_compress, ssgd_compress
from repro.data import classification_dataset, split_workers, synthetic_lm_batch
from repro.optim import adamw, momentum, sgd


def test_qsgd_unbiased():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(64).astype(np.float32))}
    keys = jax.random.split(jax.random.PRNGKey(0), 3000)
    outs = jax.vmap(lambda k: qsgd_compress(k, g, bits=2)[0]["w"])(keys)
    # b=2 quantization noise std ~ ||v||/3 per coord; mean of 3000 draws has
    # std ~0.05 -> 0.15 is a 3-sigma bound
    np.testing.assert_allclose(np.asarray(jnp.mean(outs, 0)), np.asarray(g["w"]),
                               atol=0.15)


def test_ssgd_unbiased_and_sparse():
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(128).astype(np.float32))}
    keys = jax.random.split(jax.random.PRNGKey(1), 3000)
    outs, bits = jax.vmap(lambda k: ssgd_compress(k, g, density=0.25))(keys)
    np.testing.assert_allclose(np.asarray(jnp.mean(outs["w"], 0)),
                               np.asarray(g["w"]), atol=0.12)
    frac = float(jnp.mean((outs["w"] != 0).astype(jnp.float32)))
    assert frac < 0.6                       # sparse on average


def test_optimizers_descend():
    def loss(p):
        return jnp.sum(jnp.square(p["x"] - 3.0))
    for opt in (sgd(), momentum(), adamw()):
        p = {"x": jnp.zeros((8,))}
        s = opt.init(p)
        for _ in range(200):
            g = jax.grad(loss)(p)
            p, s = opt.update(g, s, p, 0.05)
        assert float(loss(p)) < 1e-2, opt


def test_adamw_bf16_master_copy():
    opt = adamw()
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    s = opt.init(p)
    assert s.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
    p2, s2 = opt.update(g, s, p, 1e-2)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(s2.master["w"] - 1.0))) > 0  # master moved


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.npz")
        save_checkpoint(path, tree, step=17)
        restored, step = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_classification_dataset_learnable():
    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=30)
    assert X.shape == (300, 784) and Y.shape == (300, 10)
    Xw, Yw = split_workers(X, Y, 10)
    assert Xw.shape == (10, 30, 784)


def test_split_workers_heterogeneity():
    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=40)
    Xs, Ys = split_workers(X, Y, 10, heterogeneity=1.0)
    # fully sorted: each worker sees ~1 class
    per_worker_classes = [int(jnp.sum(jnp.any(Ys[w] > 0, axis=0))) for w in range(10)]
    assert np.mean(per_worker_classes) <= 3


def test_lm_batch_deterministic():
    b1 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 1000)
    b2 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert int(b1["tokens"].max()) < 1000
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


@hypothesis.given(xi=st.floats(0.01, 0.5), alpha=st.floats(0.01, 1.0))
@hypothesis.settings(max_examples=25, deadline=None)
def test_property_criterion_monotone_in_history(xi, alpha):
    """Larger parameter-motion history must only make skipping easier."""
    from repro.core import CriterionConfig, rhs_threshold
    cfg = CriterionConfig(D=5, xi=xi, t_bar=10)
    small = rhs_threshold(jnp.full((5,), 0.1), alpha, 10, 0.0, 0.0, cfg)
    large = rhs_threshold(jnp.full((5,), 10.0), alpha, 10, 0.0, 0.0, cfg)
    assert float(large) >= float(small)
