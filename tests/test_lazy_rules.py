"""Variance-aware lazy rules (core/lazy_rules.py) + shared criterion edge
cases.

Covers the LASG-WK / LASG-PS estimators and skip decisions, the regression
contract that SLAQ-WK uploads strictly more than 7a-on-noise at high
minibatch variance (the LASG paper's central failure mode of the naive
rule), and the eq.-7 edge cases every rule shares: t_bar forcing uploads,
``include_quant_error=False``, and a history ring shorter than the run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, LasgConfig, LazyState,
                        StrategyConfig, init_lazy_state, rhs_threshold,
                        run_gradient_based, run_stochastic, should_skip_rule,
                        smoothness_sq, variance_update)
from repro.core.lazy_rules import commit_upload, lazy_rule_step
from repro.data import classification_dataset, split_workers

RULES = ("laq7a", "lasg_wk", "lasg_wk2", "lasg_ps")
M = 10


# ---------------------------------------------------------------------------
# Substrates.
# ---------------------------------------------------------------------------

def logistic_setup(n_per_class=30, seed=0):
    X, Y = classification_dataset(jax.random.PRNGKey(seed),
                                  n_per_class=n_per_class)
    workers = split_workers(X, Y, M)
    N = X.shape[0]

    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * 0.01 * jnp.sum(params["w"] ** 2)) / N

    return loss_fn, {"w": jnp.zeros((10, 784))}, workers


def quadratic_problem(p=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kc, ka = jax.random.split(key)
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M
    return loss_fn, {"x": jnp.zeros((p,))}, (centers, scales)


def run_slaq(kind, *, steps=120, batch=5, bits=3, alpha=0.5,
             crit=None, seed=1):
    loss_fn, p0, workers = logistic_setup()
    crit = crit or CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)
    return run_stochastic(loss_fn, p0, workers, kind, steps=steps,
                          alpha=alpha, batch=batch, bits=bits, seed=seed,
                          laq_cfg=StrategyConfig(kind="laq", bits=bits,
                                                 criterion=crit))


# ---------------------------------------------------------------------------
# The regression contract: at high minibatch variance, eq. 7a skips on noise
# (the quant-error slack inherits the variance floor) while the WK
# correction shrinks the skip region — strictly more uploads, better loss.
# ---------------------------------------------------------------------------

def test_wk_skips_strictly_less_than_7a_at_high_variance():
    r7a = run_slaq("slaq")
    rwk = run_slaq("slaq_wk")
    up7a, upwk = int(r7a.cum_uploads[-1]), int(rwk.cum_uploads[-1])
    # 7a-on-noise over-skips by an order of magnitude; WK must upload
    # strictly more (= skip strictly less)
    assert upwk > up7a, (upwk, up7a)
    # ... and converts those uploads into a strictly better final loss
    assert float(rwk.loss[-1]) < float(r7a.loss[-1])


def test_wk_lhs_never_below_7a_lhs():
    """Pointwise guarantee behind the regression: the WK correction only
    shrinks the skip region, for any nonneg variance estimates."""
    key = jax.random.PRNGKey(0)
    hist = jax.random.uniform(key, (10,))
    crit = CriterionConfig(D=10, xi=0.08, t_bar=100)
    lasg = LasgConfig()
    for i in range(20):
        k = jax.random.fold_in(key, i)
        inn, s1, s2, eps = jax.random.uniform(k, (4,)) * 3.0
        skip_wk = should_skip_rule(
            "lasg_wk", lasg, crit, theta_hist=hist, alpha=0.3, M=M,
            eps_sq=eps, eps_hat_sq=eps, clock=jnp.int32(0),
            innovation_sq=inn, sigma_sq=s1, sigma_hat_sq=s2)
        skip_7a = should_skip_rule(
            "laq7a", lasg, crit, theta_hist=hist, alpha=0.3, M=M,
            eps_sq=eps, eps_hat_sq=eps, clock=jnp.int32(0),
            innovation_sq=inn)
        assert (not bool(skip_wk)) or bool(skip_7a)


def test_ps_skips_and_matches_sgd_loss():
    """PS saves an order of magnitude of rounds vs dense SGD while landing
    at the same loss level (its trigger is noise-free server state)."""
    rps = run_slaq("slaq_ps", steps=150)
    rsgd = run_slaq("sgd", steps=150)
    dense_uploads = 150 * M
    assert int(rps.cum_uploads[-1]) < 0.5 * dense_uploads
    assert float(rps.loss[-1]) < 1.5 * float(rsgd.loss[-1])


# ---------------------------------------------------------------------------
# Estimators.
# ---------------------------------------------------------------------------

def test_variance_estimator_converges_to_true_variance():
    key = jax.random.PRNGKey(0)
    p, sigma = 50, 0.7
    true_var = p * sigma ** 2          # E||g - mean||^2 for iid coords
    lz = init_lazy_state("lasg_wk", {"x": jnp.zeros((p,))}, 1,
                         worker_dim=False)
    cfg = LasgConfig(var_decay=0.9)
    for i in range(300):
        g = {"x": 1.5 + sigma * jax.random.normal(jax.random.fold_in(key, i),
                                                  (p,))}
        sigma_sq, lz = variance_update(lz, g, cfg)
    assert 0.7 * true_var < float(sigma_sq) < 1.4 * true_var


def test_smoothness_estimator_forces_upload_until_observed():
    lz = init_lazy_state("lasg_ps", {"x": jnp.zeros((4,))}, 1,
                         worker_dim=False)
    cfg = LasgConfig()
    assert not np.isfinite(float(smoothness_sq(lz, cfg)))   # -> upload
    # an upload with nonzero drift feeds the ratio EMA
    params = {"x": jnp.ones((4,))}
    lz2 = commit_upload("lasg_ps", cfg, lz, jnp.asarray(True),
                        {"drift_sq": jnp.float32(4.0),
                         "sigma_sq": jnp.float32(0.0)},
                        params=params, innovation_sq=jnp.float32(8.0))
    est = float(smoothness_sq(lz2, cfg))
    assert np.isclose(est, 2.0)        # ratio 8/4, debiased single sample
    np.testing.assert_array_equal(np.asarray(lz2.theta_last["x"]),
                                  np.ones((4,)))
    # a skipped round must freeze theta_last and the EMA
    lz3 = commit_upload("lasg_ps", cfg, lz2, jnp.asarray(False),
                        {"drift_sq": jnp.float32(9.0),
                         "sigma_sq": jnp.float32(0.0)},
                        params={"x": jnp.full((4,), 5.0)},
                        innovation_sq=jnp.float32(1.0))
    assert float(smoothness_sq(lz3, cfg)) == est
    np.testing.assert_array_equal(np.asarray(lz3.theta_last["x"]),
                                  np.ones((4,)))


def test_ps_estimator_not_poisoned_by_nonzero_init_params():
    """Regression: theta_last initializes to the *initial iterate*, not
    zeros — otherwise the first 'drift' observation would be
    ||theta_0||^2 and a nonzero-init run (the LM launch path) would record
    a garbage Lhat^2 ratio at the bootstrap round."""
    loss_fn, p0, data = quadratic_problem()
    theta0 = {"x": jnp.full((20,), 3.0)}          # far from zero
    cfg = StrategyConfig(kind="laq", bits=6, lazy_rule="lasg_ps",
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=100))
    from repro.core import init_comm_state, aggregate

    state = init_comm_state(theta0, M, cfg)
    np.testing.assert_array_equal(
        np.asarray(state.lazy.theta_last["x"][0]), np.asarray(theta0["x"]))
    grad_m = jax.grad(loss_fn)
    grads = jax.vmap(lambda d: grad_m(theta0, d))(data)
    _, state, _ = aggregate(state, grads, 0.3, cfg, params=theta0)
    # bootstrap round: everyone uploads (no Lhat yet), drift is exactly 0,
    # so NO ratio is observed — the estimator stays unbiased-virgin
    assert float(jnp.max(state.lazy.stat_count)) == 0.0
    assert float(jnp.max(state.lazy.stat_ema)) == 0.0
    # and a full run from the same nonzero init converges under PS
    r = run_gradient_based(loss_fn, theta0, data, cfg, steps=300, alpha=0.3)
    assert float(r.grad_norm_sq[-1]) < 1e-3
    # skipping actually happens (the estimator recovers real ratios)
    assert int(r.cum_uploads[-1]) < 0.8 * 300 * M


def test_wk2_same_sample_difference_is_noise_free():
    """Unit contract behind WK2: the LHS is exactly the squared distance of
    the two same-sample gradients — shared noise cancels by construction.
    Feeding g and g + drift (the same noise realization on both sides)
    yields LHS = ||drift||^2 regardless of the noise magnitude."""
    key = jax.random.PRNGKey(7)
    noise = 100.0 * jax.random.normal(key, (32,))      # huge shared noise
    drift = jnp.full((32,), 0.01)
    g_now = {"x": noise + drift}
    g_stale = {"x": noise}
    lz = init_lazy_state("lasg_wk2", {"x": jnp.zeros((32,))}, 1,
                         worker_dim=False)
    # mark the worker as past its bootstrap upload (a virgin state forces
    # an upload regardless of the LHS — tested separately below)
    lz = lz._replace(stat_count=jnp.float32(1.0))
    skip, _, _ = lazy_rule_step(
        "lasg_wk2", LasgConfig(), CriterionConfig(D=10, xi=0.08, t_bar=100),
        grad_m=g_now, params={"x": jnp.zeros((32,))}, lazy_m=lz,
        innovation_sq=jnp.float32(1e6),   # noisy innovation is NOT the LHS
        err_sq=jnp.float32(0.0), eps_hat_sq_m=jnp.float32(0.0),
        clock_m=jnp.int32(0),
        theta_hist=jnp.full((10,), 10.0, jnp.float32), alpha=0.3,
        n_workers=M, grad_stale_m=g_stale)
    # ||drift||^2 = 32 * 1e-4 = 3.2e-3 << threshold -> skip, even though
    # the (noise-dominated) innovation would have forced an upload under 7a
    assert bool(skip)


def test_wk2_requires_stale_gradient_and_state():
    lz = init_lazy_state("lasg_wk2", {"x": jnp.zeros((4,))}, 1,
                         worker_dim=False)
    kw = dict(grad_m={"x": jnp.zeros((4,))}, params={"x": jnp.zeros((4,))},
              innovation_sq=jnp.float32(0), err_sq=jnp.float32(0),
              eps_hat_sq_m=jnp.float32(0), clock_m=jnp.int32(0),
              theta_hist=jnp.zeros((10,)), alpha=0.3, n_workers=M)
    with pytest.raises(ValueError, match="grad_stale_m"):
        lazy_rule_step("lasg_wk2", LasgConfig(), CriterionConfig(),
                       lazy_m=lz, **kw)
    with pytest.raises(ValueError, match="params"):
        lazy_rule_step("lasg_wk2", LasgConfig(), CriterionConfig(),
                       lazy_m=lz, **{**kw, "params": None},
                       grad_stale_m={"x": jnp.zeros((4,))})
    from repro.core.lazy_rules import empty_lazy_state
    with pytest.raises(ValueError, match="theta_last"):
        lazy_rule_step("lasg_wk2", LasgConfig(), CriterionConfig(),
                       lazy_m=empty_lazy_state(), **kw,
                       grad_stale_m={"x": jnp.zeros((4,))})


def test_wk2_bootstrap_guard_without_forced_first_round():
    """Regression: with ``first_round_upload=False`` the init-time
    ``theta_last`` equals the current iterate, so the same-sample LHS is
    exactly zero and — without the guard — every worker would skip while
    params never move, a self-sustaining freeze until t_bar.  The guard
    forces each worker's first upload instead, so round 0 is dense and the
    run converges."""
    loss_fn, p0, data = quadratic_problem()
    cfg = StrategyConfig(kind="laq", bits=6, lazy_rule="lasg_wk2",
                         first_round_upload=False,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=100))
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=200, alpha=0.3)
    assert int(r.cum_uploads[0]) == M        # bootstrap round is dense
    assert float(r.grad_norm_sq[-1]) < 1e-3  # and the run converges
    assert int(r.cum_uploads[-1]) < 0.8 * 200 * M   # skipping still happens


def test_wk2_commit_snapshots_theta_last_on_upload_only():
    lz = init_lazy_state("lasg_wk2", {"x": jnp.zeros((4,))}, 1,
                         worker_dim=False)
    cfg = LasgConfig()
    up = commit_upload("lasg_wk2", cfg, lz, jnp.asarray(True),
                       {"sigma_sq": jnp.float32(0), "drift_sq": jnp.float32(0)},
                       params={"x": jnp.full((4,), 2.0)},
                       innovation_sq=jnp.float32(0))
    np.testing.assert_array_equal(np.asarray(up.theta_last["x"]),
                                  np.full((4,), 2.0))
    kept = commit_upload("lasg_wk2", cfg, up, jnp.asarray(False),
                         {"sigma_sq": jnp.float32(0), "drift_sq": jnp.float32(0)},
                         params={"x": jnp.full((4,), 9.0)},
                         innovation_sq=jnp.float32(0))
    np.testing.assert_array_equal(np.asarray(kept.theta_last["x"]),
                                  np.full((4,), 2.0))


def test_ps_requires_params():
    lz = init_lazy_state("lasg_ps", {"x": jnp.zeros((4,))}, 1,
                         worker_dim=False)
    with pytest.raises(ValueError, match="params"):
        lazy_rule_step("lasg_ps", LasgConfig(), CriterionConfig(),
                       grad_m={"x": jnp.zeros((4,))}, params=None,
                       lazy_m=lz, innovation_sq=jnp.float32(0),
                       err_sq=jnp.float32(0), eps_hat_sq_m=jnp.float32(0),
                       clock_m=jnp.int32(0), theta_hist=jnp.zeros((10,)),
                       alpha=0.3, n_workers=M)


# ---------------------------------------------------------------------------
# Criterion edge cases shared by all three rules.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", RULES)
def test_t_bar_forces_upload_under_every_rule(rule):
    """(7b) is rule-independent: with t_bar = 5 every worker uploads at
    least once every 6 rounds even when the rule's (7a)-side always skips
    (huge xi makes the threshold astronomically large)."""
    loss_fn, p0, data = quadratic_problem()
    crit = CriterionConfig(D=5, xi=1e6, t_bar=5)
    cfg = StrategyConfig(kind="laq", bits=6, criterion=crit, lazy_rule=rule)
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=60, alpha=0.3)
    ups = np.asarray(r.cum_uploads)
    assert int(ups[-1]) >= M * (60 // 6)
    # and between forced refreshes everyone skips: no more than the forced
    # cadence plus the dense bootstrap round
    assert int(ups[-1]) <= M * (60 // 6 + 1)


def test_include_quant_error_false_tightens_rhs_and_uploads_more():
    eps = jnp.float32(0.5)
    hist = jnp.ones((10,), jnp.float32)
    with_slack = rhs_threshold(hist, 0.3, M, eps, eps,
                               CriterionConfig(include_quant_error=True))
    without = rhs_threshold(hist, 0.3, M, eps, eps,
                            CriterionConfig(include_quant_error=False))
    assert np.isclose(float(without), float(with_slack) - 3.0 * float(eps + eps),
                      rtol=1e-5, atol=1e-6)

    loss_fn, p0, data = quadratic_problem()

    def run(include):
        crit = CriterionConfig(D=10, xi=0.08, t_bar=100,
                               include_quant_error=include)
        cfg = StrategyConfig(kind="laq", bits=3, criterion=crit)
        return run_gradient_based(loss_fn, p0, data, cfg, steps=200,
                                  alpha=0.3)

    r_with, r_without = run(True), run(False)
    # dropping the slack can only shrink the skip region
    assert int(r_without.cum_uploads[-1]) >= int(r_with.cum_uploads[-1])


@pytest.mark.parametrize("rule", RULES)
def test_history_shorter_than_run(rule):
    """D = 3 against a 150-step run: the ring wraps ~50 times and the run
    still converges under every rule."""
    loss_fn, p0, data = quadratic_problem()
    crit = CriterionConfig(D=3, xi=0.8 / 3, t_bar=50)
    cfg = StrategyConfig(kind="laq", bits=6, criterion=crit, lazy_rule=rule)
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=150, alpha=0.3)
    assert float(r.grad_norm_sq[-1]) < 1e-3
    assert np.isfinite(float(r.loss[-1]))


# ---------------------------------------------------------------------------
# State plumbing.
# ---------------------------------------------------------------------------

def test_lazy_state_allocation_matches_rule():
    tmpl = {"x": jnp.zeros((7,))}
    s7 = init_lazy_state("laq7a", tmpl, 4)
    assert s7.grad_ema is None and s7.theta_last is None
    swk = init_lazy_state("lasg_wk", tmpl, 4)
    assert swk.grad_ema["x"].shape == (4, 7) and swk.theta_last is None
    swk2 = init_lazy_state("lasg_wk2", tmpl, 4)
    assert swk2.theta_last["x"].shape == (4, 7) and swk2.grad_ema is None
    sps = init_lazy_state("lasg_ps", tmpl, 4)
    assert sps.theta_last["x"].shape == (4, 7) and sps.grad_ema is None
    assert isinstance(s7, LazyState)


@pytest.mark.parametrize("rule", ("lasg_wk", "lasg_wk2", "lasg_ps"))
def test_rules_run_deterministically_too(rule):
    """The rules are not stochastic-only plumbing: a full-gradient run
    converges (WK's variance estimate then only measures drift, which makes
    it conservative, never wrong)."""
    loss_fn, p0, data = quadratic_problem()
    cfg = StrategyConfig(kind="laq", bits=6, lazy_rule=rule,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=100))
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=300, alpha=0.3)
    assert float(r.grad_norm_sq[-1]) < 1e-4


# ---------------------------------------------------------------------------
# NaN hardening.
# ---------------------------------------------------------------------------

def test_ps_lhs_guard_pins_inf_times_zero():
    """The explicit isfinite guard in rule_lhs: before the first ratio
    observation L_sq is +inf while the drift can be exactly 0, and
    inf * 0 = nan would make the <= comparison silently False (an upload,
    but by accident).  The guard must return +inf — a *forced* upload — and
    never NaN."""
    from repro.core.lazy_rules import rule_lhs
    lasg = LasgConfig()
    lhs = rule_lhs("lasg_ps", lasg, drift_sq=jnp.float32(0.0),
                   L_sq=jnp.float32(jnp.inf))
    assert not np.isnan(float(lhs)) and np.isposinf(float(lhs))
    # finite smoothness: the ordinary product
    lhs2 = rule_lhs("lasg_ps", lasg, drift_sq=jnp.float32(2.0),
                    L_sq=jnp.float32(3.0))
    np.testing.assert_allclose(float(lhs2), lasg.c_ps * 6.0)


def test_nan_gradient_poisons_criterion_without_defense():
    """A NaN gradient does NOT reach the server aggregate on the quantized
    path — the R > 0 guard turns it into a zero delta — but its
    quantization-error moment err_sq = ||g - qhat||^2 = NaN commits into
    eps_hat_sq, turning the worker's criterion RHS NaN: skips are impossible
    (NaN <= x is False) until the next committed upload overwrites the
    moment, so every poison event silently costs forced uploads.  Upload
    validation (DefenseConfig.validate) finite-checks that moment and
    rejects the poison; the defended run never carries a NaN moment and
    completes at the clean run's loss."""
    from repro.core import DefenseConfig, FaultConfig, RoundEngine
    from repro.core.engine import FullBatchSource
    loss_fn, p0, data = quadratic_problem()
    crit = CriterionConfig(D=10, xi=0.08, t_bar=50)
    fc = FaultConfig(corrupt_p=0.02, corrupt_kind="nan", fault_seed=1)

    def final_state(cfg):
        eng = RoundEngine(FullBatchSource(loss_fn, data), cfg, alpha=0.3)
        carry, rr = eng.run_from(eng.init_carry(p0), 60)
        return carry[1], rr

    base = StrategyConfig(kind="laq", bits=4, criterion=crit)
    cst_clean, rr_clean = final_state(base)
    cst_bad, rr_bad = final_state(base._replace(faults=fc))
    cst_def, rr_def = final_state(base._replace(
        faults=fc, defense=DefenseConfig(validate=True)))

    # undefended: the poison lands in eps_hat_sq (params stay finite), and
    # the faulty runs pay more uploads than the clean one either way
    assert np.isnan(np.asarray(cst_bad.eps_hat_sq)).any()
    assert np.all(np.isfinite(np.asarray(rr_bad.loss)))
    assert int(cst_bad.total_uploads) > int(cst_clean.total_uploads)
    assert int(cst_def.total_uploads) > int(cst_clean.total_uploads)

    # defended: every moment stays finite, the run completes at the clean
    # loss, and the rejections were actually exercised
    assert np.all(np.isfinite(np.asarray(cst_def.eps_hat_sq)))
    assert int(jnp.sum(cst_def.defense.rejects)) >= 1
    np.testing.assert_allclose(float(rr_def.loss[-1]),
                               float(rr_clean.loss[-1]), rtol=0.05)
