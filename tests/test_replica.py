"""Lazy-replica publishing contracts (core/replica.py, launch/publish.py).

The load-bearing guarantees of docs/serving.md, pinned on BOTH wire
backends:

* a replica that applies every message equals the publisher's published
  view ``theta_pub`` **bitwise** (the decode path is expression-identical
  to the publisher's q_new accumulation);
* lazy skipping bounds the published-view staleness by the relative
  threshold (``R <= threshold * anchor`` on every skipped round);
* a ``max_staleness`` resync restores **exact** equality with the live
  trainer params and resets the error recursion;
* the two wire backends produce identical push schedules, payload bytes,
  and replica weights;
* fleet transport delay composes with laziness: replica ``r`` at round
  ``k`` serves exactly the published view of round ``k - d_r``;
* wire-bit accounting is analytic: ``dense_bits`` for snapshots,
  ``upload_bits(p, b, n_radii=L)`` per quantized push.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, PublishConfig, RoundEngine,
                        StrategyConfig)
from repro.core.adaptive import BitSchedule
from repro.core.engine import FullBatchSource
from repro.core.quantize import dense_bits, tree_size, upload_bits
from repro.core.replica import (apply_message, init_publisher, init_replica,
                                publish, staleness_drift)
from repro.launch.publish import (ReplicaFleet, publish_trajectory,
                                  trainer_rounds)

BACKENDS = ("reference", "fused")


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _trajectory(n=25, seed=0):
    """Geometrically converging iterates: theta_k = theta* + 0.8^k noise_k
    (what a training run looks like to the publisher, without the cost of
    one)."""
    k0 = jax.random.PRNGKey(seed)
    star = {"w": jax.random.normal(k0, (9, 4)),
            "b": jax.random.normal(jax.random.fold_in(k0, 1), (11,))}
    out = []
    for k in range(n):
        nk = jax.random.fold_in(k0, 100 + k)
        noise = {"w": jax.random.normal(nk, (9, 4)),
                 "b": jax.random.normal(jax.random.fold_in(nk, 1), (11,))}
        out.append(jax.tree.map(lambda s, z: s + (0.8 ** k) * z, star, noise))
    return out


@pytest.fixture(scope="module")
def traj():
    return _trajectory()


# ---------------------------------------------------------------------------
# Bitwise replica == published view; staleness bounds.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_always_push_replica_equals_published_view_bitwise(traj, backend):
    """threshold=0 pushes every round with nonzero innovation; the replica
    must track theta_pub bit-for-bit, and theta_pub must track the trainer
    within one round's quantization error (non-accumulating recursion)."""
    cfg = PublishConfig(bits=4, threshold=0.0, wire_backend=backend)
    st = init_publisher(traj[0], cfg)
    rep = init_replica(traj[0])
    for params in traj[1:]:
        msg, st = publish(cfg, st, params)
        assert msg is not None and hasattr(msg, "payloads")
        rep = apply_message(rep, msg, cfg)
        assert _tree_equal(rep.params, st.theta_pub)
    assert st.n_pushes == len(traj) - 1 and st.n_resyncs == 0
    # after the final push the view is one quantization step from the
    # trainer: |theta - theta_pub|_inf <= 2*tau(b)*R of that push
    assert staleness_drift(traj[-1], rep) < 2.0 / (2 ** 4 - 1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_lazy_skip_bounds_drift_by_relative_threshold(traj, backend):
    """On every skipped round the innovation radius obeys the lazy rule:
    R <= threshold * anchor — the freshness guarantee serving relies on."""
    cfg = PublishConfig(bits=4, threshold=0.4, max_staleness=100,
                        wire_backend=backend)
    st = init_publisher(traj[0], cfg)
    rep = init_replica(traj[0])
    n_skips = 0
    for params in traj[1:]:
        prev_anchor = float(st.R_anchor)
        msg, st = publish(cfg, st, params)
        rep = apply_message(rep, msg, cfg)
        if msg is None:
            n_skips += 1
            # the anchor only ever decays between pushes, so the skipped
            # round's R is bounded by threshold * (this round's anchor)
            drift = staleness_drift(params, rep)
            anchor = max(float(st.R_anchor), prev_anchor)
            assert drift <= cfg.threshold * anchor + 1e-7
        else:
            assert _tree_equal(rep.params, st.theta_pub)
    assert n_skips > 0, "threshold=0.4 on a converging run must skip"
    assert st.n_pushes + n_skips == len(traj) - 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_max_staleness_resync_restores_exact_equality(traj, backend):
    """threshold >= 1 never lazily pushes, so every max_staleness+1 rounds
    the publisher must cut a full-precision resync that makes the replica
    bitwise equal to the live trainer params."""
    cfg = PublishConfig(threshold=1.5, max_staleness=3, wire_backend=backend)
    st = init_publisher(traj[0], cfg)
    rep = init_replica(traj[0])
    resync_rounds = []
    for k, params in enumerate(traj[1:]):
        msg, st = publish(cfg, st, params)
        rep = apply_message(rep, msg, cfg)
        if msg is not None:
            assert not hasattr(msg, "payloads"), "threshold>=1 never pushes"
            resync_rounds.append(k)
            assert _tree_equal(rep.params, params)
            assert _tree_equal(st.theta_pub, params)
            assert st.rounds_behind == 0
        else:
            assert rep.rounds_behind <= cfg.max_staleness
    assert resync_rounds, "a converging run must trip the staleness bound"
    # the skip counter is bounded: resyncs land every max_staleness+1 rounds
    gaps = np.diff([-1] + resync_rounds)
    assert (gaps == cfg.max_staleness + 1).all()
    assert st.n_resyncs == len(resync_rounds) and st.n_pushes == 0
    # exact accounting: resyncs are dense snapshots
    p = tree_size(traj[0])
    assert st.bits_sent == dense_bits(p) * (1 + st.n_resyncs)


def test_zero_innovation_skips_without_resync(traj):
    """A stationary trainer (R == 0) must stay silent forever — bounded
    staleness is about unseen *change*, not wall-clock."""
    cfg = PublishConfig(threshold=0.25, max_staleness=2)
    st = init_publisher(traj[0], cfg)
    for _ in range(10):
        msg, st = publish(cfg, st, traj[0])
        assert msg is None
    assert st.n_resyncs == 0 and st.n_pushes == 0
    assert st.rounds_behind == 10


# ---------------------------------------------------------------------------
# Backend parity; adaptive width; accounting.
# ---------------------------------------------------------------------------

def test_backend_parity_schedule_payloads_and_weights(traj):
    """Reference and fused backends must agree on the push schedule, the
    payload bytes on the wire, and the resulting replica weights."""
    reps, sts, payloads = {}, {}, {}
    for backend in BACKENDS:
        cfg = PublishConfig(bits=4, threshold=0.35, max_staleness=5,
                            wire_backend=backend)
        st = init_publisher(traj[0], cfg)
        rep = init_replica(traj[0])
        sched, raw = [], []
        for params in traj[1:]:
            msg, st = publish(cfg, st, params)
            rep = apply_message(rep, msg, cfg)
            sched.append(None if msg is None
                         else "p" if hasattr(msg, "payloads") else "r")
            if msg is not None and hasattr(msg, "payloads"):
                raw.append([np.asarray(x) for x in msg.payloads])
        reps[backend], sts[backend], payloads[backend] = rep, st, (sched, raw)
    assert payloads["reference"][0] == payloads["fused"][0]
    for mr, mf in zip(payloads["reference"][1], payloads["fused"][1]):
        for lr, lf in zip(mr, mf):
            # fused payloads are BLOCK-padded; the common prefix (all real
            # codes live there) must match byte-for-byte
            n = min(lr.size, lf.size)
            np.testing.assert_array_equal(lr[:n], lf[:n])
    assert _tree_equal(reps["reference"].params, reps["fused"].params)
    assert sts["reference"].bits_sent == sts["fused"].bits_sent


@pytest.mark.parametrize("backend", BACKENDS)
def test_adaptive_width_pushes_decode_bitwise(traj, backend):
    """With a rel-mode BitSchedule the per-push width varies; the replica
    decodes through the width announced in the message and still matches
    theta_pub bitwise."""
    cfg = PublishConfig(threshold=0.0, wire_backend=backend,
                        bit_schedule=BitSchedule(kind="radius", grid=(2, 4, 8),
                                                 threshold_mode="rel",
                                                 thresholds=(0.05, 0.5)))
    st = init_publisher(traj[0], cfg)
    rep = init_replica(traj[0])
    widths = []
    for params in traj[1:]:
        msg, st = publish(cfg, st, params)
        rep = apply_message(rep, msg, cfg)
        if msg is not None:
            widths.append(msg.width)
            assert _tree_equal(rep.params, st.theta_pub)
    assert set(widths) <= {2, 4, 8}
    assert len(set(widths)) > 1, "radius decay must move the width"
    # accounting carries the 8-bit width sidecar
    p = tree_size(traj[0])
    L = len(jax.tree.leaves(traj[0]))
    expect = dense_bits(p) + sum(
        upload_bits(p, b, n_radii=L, bit_sidecar=True) for b in widths)
    assert st.bits_sent == expect


def test_always_push_bits_accounting_is_analytic(traj):
    """bits_sent == init dense snapshot + K * upload_bits(p, b, L)."""
    cfg = PublishConfig(bits=8, threshold=0.0)
    st = init_publisher(traj[0], cfg)
    for params in traj[1:]:
        _, st = publish(cfg, st, params)
    p = tree_size(traj[0])
    L = len(jax.tree.leaves(traj[0]))
    assert st.bits_sent == dense_bits(p) + st.n_pushes * upload_bits(
        p, 8, n_radii=L)


def test_config_validation():
    with pytest.raises(AssertionError):
        PublishConfig(bits=3).validate()
    with pytest.raises(AssertionError):
        PublishConfig(threshold=-0.1).validate()
    with pytest.raises(AssertionError):  # abs-mode schedule has no anchor
        PublishConfig(bit_schedule=BitSchedule(
            kind="radius", grid=(2, 4, 8), threshold_mode="abs",
            thresholds=(0.1, 1.0))).validate()


# ---------------------------------------------------------------------------
# Fleet: transport delay composes with laziness.
# ---------------------------------------------------------------------------

def test_fleet_delay_serves_the_delayed_published_view(traj):
    """Replica r (delay d_r = r mod (max_delay+1)) at round k holds exactly
    the published view of round k - d_r — transport delay is just a shifted
    subscription, not a different protocol."""
    cfg = PublishConfig(bits=4, threshold=0.3, max_staleness=4)
    st = init_publisher(traj[0], cfg)
    fleet = ReplicaFleet(traj[0], 3, cfg, max_delay=2)
    views = [st.theta_pub]  # published view after each round; [0] = init
    for params in traj[1:]:
        msg, st = publish(cfg, st, params)
        fleet.deliver(msg)
        views.append(st.theta_pub)
        for r, d in enumerate(fleet.delays):
            want = views[max(0, len(views) - 1 - d)]
            assert _tree_equal(fleet.replicas[r].params, want)
    assert max(fleet.freshness()) <= cfg.max_staleness + 2  # + max_delay


def test_fleet_synchronous_equals_single_replica(traj):
    cfg = PublishConfig(bits=4, threshold=0.3, max_staleness=4)
    st = init_publisher(traj[0], cfg)
    rep = init_replica(traj[0])
    fleet = ReplicaFleet(traj[0], 2, cfg, max_delay=0)
    for params in traj[1:]:
        msg, st = publish(cfg, st, params)
        rep = apply_message(rep, msg, cfg)
        fleet.deliver(msg)
    for fr in fleet.replicas:
        assert _tree_equal(fr.params, rep.params)


# ---------------------------------------------------------------------------
# End to end against a real RoundEngine trainer.
# ---------------------------------------------------------------------------

def _quadratic(M=6, p=16, seed=3):
    key = jax.random.PRNGKey(seed)
    kc, ka = jax.random.split(key)
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M
    return loss_fn, {"x": jnp.zeros((p,))}, (centers, scales)


def test_publish_trajectory_over_engine_rounds():
    """The full driver: a LAQ RoundEngine trainer feeds publish_trajectory;
    the attached fleet stays within the configured staleness budget and its
    drift against the live trainer decays with the iterates."""
    loss_fn, p0, data = _quadratic()
    eng = RoundEngine(FullBatchSource(loss_fn, data),
                      StrategyConfig(kind="laq", bits=8, per_leaf_radius=True,
                                     criterion=CriterionConfig(D=10, xi=0.08,
                                                               t_bar=100)),
                      alpha=0.3)
    cfg = PublishConfig(bits=4, threshold=0.3, max_staleness=4)
    st = init_publisher(p0, cfg)
    fleet = ReplicaFleet(p0, 2, cfg, max_delay=1)
    st, rows = publish_trajectory(trainer_rounds(eng, p0, 40), cfg, st,
                                  fleet=fleet)
    assert len(rows) == 40
    kinds = {r["kind"] for r in rows}
    assert "push" in kinds and "skip" in kinds, \
        "a converging trainer must both push and skip"
    assert max(r["fleet_max_behind"] for r in rows) <= cfg.max_staleness + 1
    # monotone bits, and the tail drift is small compared to the head
    bits = [r["bits_sent"] for r in rows]
    assert all(b2 >= b1 for b1, b2 in zip(bits, bits[1:]))
    drifts = [r["fleet_max_drift"] for r in rows]
    assert np.mean(drifts[-5:]) < 0.1 * (np.mean(drifts[:5]) + 1e-12)
