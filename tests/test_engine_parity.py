"""Golden seeded bitwise parity for the unified round engine.

The PR-5 refactor moved the per-round protocol of both simulated runners
into ``core/engine.py`` (`RoundEngine` + pluggable `GradientSource` /
`ParticipationModel` stages) and turned ``run_gradient_based`` /
``run_stochastic`` into thin wrappers.  The contract pinned here: every
pre-existing kind x lazy_rule x grad_mode x wire_backend combination
reproduces its **pre-refactor seeded trajectory bitwise** — loss, upload
and bit accounting, radius diagnostics and final parameters.

The goldens in ``tests/data/engine_goldens.npz`` were captured by running
this module as a script against the pre-engine runners (commit f9ddad2):

    PYTHONPATH=src python tests/test_engine_parity.py   # regenerates npz

with ONE amendment: the gradient-family entries carry the PR-5 perf fix
(``grad_norm_sq`` from the summed per-worker gradients instead of a third
``jax.grad(global_loss)`` backprop) applied as a one-line change to the
OLD runner before capture.  The fix is mathematically a no-op (the summed
full local gradients ARE the global gradient) but removing the extra
backprop changes XLA fusion, which perturbs the ``lag`` trajectory at the
last-ulp level (~1e-7) — so pinning the engine against old-runner-plus-fix
cleanly separates "the refactor changed nothing" (bitwise, asserted here)
from "the mandated perf fix moved fusion ulps" (captured once, upstream of
the refactor).

Regenerate ONLY when a change is *supposed* to alter trajectories (then say
so in the PR); an unintentional diff here means the engine decomposition
changed the round math.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, StrategyConfig, run_gradient_based,
                        run_stochastic)

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "data", "engine_goldens.npz")

GRAD_KINDS = ("gd", "qgd", "lag", "laq")
STOCH_CASES = (
    ("sgd", "sgd"), ("qsgd", "sgd"), ("ssgd", "sgd"),
    ("slaq", "sgd"), ("slaq_wk", "sgd"), ("slaq_wk2", "sgd"),
    ("slaq_ps", "sgd"),
    ("slaq", "svrg"), ("slaq_wk2", "svrg"),
)
BACKENDS = ("reference", "fused")


# ---------------------------------------------------------------------------
# Fixtures: the deterministic quadratic of tests/test_strategy.py and the
# stochastic linear regression of tests/test_wire_backend.py.
# ---------------------------------------------------------------------------

def quadratic_problem(M=10, p=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kc, ka = jax.random.split(key)
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M

    return loss_fn, {"x": jnp.zeros((p,))}, (centers, scales)


def regression_problem(M=6, n_local=12, p=8, seed=3):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    X = jax.random.normal(kx, (M, n_local, p))
    w_true = jnp.linspace(-1.0, 1.0, p)
    Yn = X @ w_true + 0.3 * jax.random.normal(ky, (M, n_local))

    def loss_fn(params, data):
        x, y = data
        return 0.5 * jnp.sum(jnp.square(x @ params["w"] - y)) / (M * n_local)

    return loss_fn, {"w": jnp.zeros((p,))}, (X, Yn)


def run_grad_case(kind, backend):
    loss_fn, p0, data = quadratic_problem()
    cfg = StrategyConfig(kind=kind, bits=4, wire_backend=backend,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=100))
    return run_gradient_based(loss_fn, p0, data, cfg, steps=60, alpha=0.3)


def run_stoch_case(kind, grad_mode, backend):
    loss_fn, p0, data = regression_problem()
    cfg = StrategyConfig(kind="laq", bits=4, wire_backend=backend,
                         criterion=CriterionConfig(D=10, xi=0.08, t_bar=20),
                         grad_mode=grad_mode, svrg_period=7)
    return run_stochastic(loss_fn, p0, data, kind, steps=50, alpha=0.3,
                          batch=4, bits=4, seed=2, laq_cfg=cfg)


def fingerprint(result, *, with_grad_norm):
    """The trajectory fields under the bitwise contract (see docstring)."""
    out = {
        "loss": np.asarray(result.loss),
        "cum_uploads": np.asarray(result.cum_uploads),
        "cum_bits": np.asarray(result.cum_bits),
        "quant_err": np.asarray(result.quant_err),
        "mean_bits": np.asarray(result.mean_bits),
    }
    if with_grad_norm:
        out["grad_norm_sq"] = np.asarray(result.grad_norm_sq)
    for i, leaf in enumerate(jax.tree.leaves(result.params)):
        out[f"params{i}"] = np.asarray(leaf)
    return out


def _goldens():
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden file missing: {GOLDEN_PATH} — regenerate with "
                    "`PYTHONPATH=src python tests/test_engine_parity.py`")
    return np.load(GOLDEN_PATH)


def _assert_matches(goldens, tag, fp):
    for field, val in fp.items():
        key = f"{tag}/{field}"
        assert key in goldens.files, f"golden missing {key}"
        np.testing.assert_array_equal(
            val, goldens[key],
            err_msg=f"{key}: engine-backed wrapper diverged bitwise from "
                    "the pre-refactor trajectory")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", GRAD_KINDS)
def test_gradient_trajectory_matches_pre_refactor(kind, backend):
    fp = fingerprint(run_grad_case(kind, backend), with_grad_norm=True)
    _assert_matches(_goldens(), f"grad/{kind}/{backend}", fp)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind,grad_mode", STOCH_CASES)
def test_stochastic_trajectory_matches_pre_refactor(kind, grad_mode, backend):
    fp = fingerprint(run_stoch_case(kind, grad_mode, backend),
                     with_grad_norm=True)
    _assert_matches(_goldens(), f"stoch/{kind}/{grad_mode}/{backend}", fp)


def _capture():
    arrays = {}
    for kind in GRAD_KINDS:
        for backend in BACKENDS:
            fp = fingerprint(run_grad_case(kind, backend),
                             with_grad_norm=True)
            arrays.update({f"grad/{kind}/{backend}/{f}": v
                           for f, v in fp.items()})
            print(f"captured grad/{kind}/{backend}")
    for kind, grad_mode in STOCH_CASES:
        for backend in BACKENDS:
            fp = fingerprint(run_stoch_case(kind, grad_mode, backend),
                             with_grad_norm=True)
            arrays.update({f"stoch/{kind}/{grad_mode}/{backend}/{f}": v
                           for f, v in fp.items()})
            print(f"captured stoch/{kind}/{grad_mode}/{backend}")
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    np.savez_compressed(GOLDEN_PATH, **arrays)
    print(f"wrote {len(arrays)} arrays -> {GOLDEN_PATH}")


if __name__ == "__main__":
    _capture()
