"""Bit-identity of the pluggable wire backends (core/wire.py).

The contract under test: the ``fused`` two-pass backend produces the same
wire bits as the ``reference`` jnp path across the full
{qgd, laq} x bits {2, 4, 8} x {global, per-leaf} grid — bitwise for the
wire content (codes, radii, delta, q_new) and for whole simulated LAQ
trajectories; scalar criterion moments to f32 reduction accuracy (see the
core/wire.py docstring for why the last ulp is fusion-dependent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitSchedule, CriterionConfig, StrategyConfig,
                        run_gradient_based, run_stochastic, worker_update)
from repro.core.quantize import innovation
from repro.core.strategy import aggregate, init_comm_state
from repro.core.wire import (FusedWire, axis_packable, get_backend,
                             pack_codes_along_axis, unpack_codes_along_axis)

BITS = (2, 4, 8)
RADII = (False, True)
GRID = (2, 4, 8)       # adaptive bit_schedule grid under test


def _tree(seed=0):
    """Leaf sizes chosen to exercise padding: odd, non-multiple-of-8/b,
    multi-dim, and > one kernel block."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    return {
        "w1": jax.random.normal(ks[0], (300,)) * 2,
        "w2": jax.random.normal(ks[1], (17, 5)),
        "w3": jax.random.normal(ks[2], (4097,)) * 0.3,
        "b": jax.random.normal(ks[3], (1,)),
    }


def _qhat(seed=10):
    t = _tree(seed)
    return jax.tree.map(lambda l: 0.5 * l, t)


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("per_leaf", RADII)
def test_roundtrip_wire_content_bit_identical(bits, per_leaf):
    g, qh = _tree(), _qhat()
    ref = jax.jit(lambda g, qh: get_backend("reference").roundtrip(
        g, qh, bits, per_leaf))(g, qh)
    fus = jax.jit(lambda g, qh: get_backend("fused").roundtrip(
        g, qh, bits, per_leaf))(g, qh)
    assert _trees_equal(ref.delta, fus.delta)
    assert _trees_equal(ref.q_new, fus.q_new)
    assert _trees_equal(ref.R_tree, fus.R_tree)
    assert float(ref.R_max) == float(fus.R_max)
    np.testing.assert_allclose(float(fus.err_sq), float(ref.err_sq),
                               rtol=1e-6)
    np.testing.assert_allclose(float(fus.innovation_sq),
                               float(ref.innovation_sq), rtol=1e-6)


@pytest.mark.parametrize("kind", ["qgd", "laq"])
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("per_leaf", RADII)
def test_worker_update_bit_identical(kind, bits, per_leaf):
    """The state machine sees identical wire bits: masked delta, new qhat,
    upload decision, eps state and wire-bit accounting all match bitwise."""
    g, qh = _tree(), _qhat()
    theta_hist = jnp.full((10,), 0.3, jnp.float32)
    crit = CriterionConfig(D=10, xi=0.08, t_bar=100)

    def upd(backend):
        cfg = StrategyConfig(kind=kind, bits=bits, per_leaf_radius=per_leaf,
                             criterion=crit, wire_backend=backend)
        return jax.jit(lambda g, qh: worker_update(
            g, qh, jnp.float32(0.05), jnp.int32(3), jnp.float32(0.0),
            theta_hist, 0.1, 10, cfg))(g, qh)

    r = upd("reference")
    f = upd("fused")
    names = ("delta_masked", "qhat_new", "eps_hat_sq", "clock", "uploaded",
             "bits_m", "R", "width")
    for name, a, b in zip(names, r, f):
        assert _trees_equal(a, b), f"{name} differs across wire backends"


@pytest.mark.parametrize("kind", ["qgd", "laq"])
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("per_leaf", RADII)
def test_trajectory_bit_identical(kind, bits, per_leaf):
    """A whole simulated multi-worker run (vmap + scan, skip criterion in
    the loop) reproduces the identical trajectory on either backend."""
    key = jax.random.PRNGKey(0)
    kc, ka = jax.random.split(key)
    M, p = 10, 20
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M

    p0 = {"x": jnp.zeros((p,))}

    def run(backend):
        cfg = StrategyConfig(kind=kind, bits=bits, per_leaf_radius=per_leaf,
                             criterion=CriterionConfig(D=10, xi=0.08, t_bar=100),
                             wire_backend=backend)
        return run_gradient_based(loss_fn, p0, (centers, scales), cfg,
                                  steps=120, alpha=0.3)

    rr, rf = run("reference"), run("fused")
    np.testing.assert_array_equal(np.asarray(rr.loss), np.asarray(rf.loss))
    np.testing.assert_array_equal(np.asarray(rr.cum_bits),
                                  np.asarray(rf.cum_bits))
    np.testing.assert_array_equal(np.asarray(rr.cum_uploads),
                                  np.asarray(rf.cum_uploads))
    np.testing.assert_array_equal(np.asarray(rr.params["x"]),
                                  np.asarray(rf.params["x"]))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("variant", ["wk2", "svrg", "wk2+svrg"])
def test_stochastic_trajectory_bit_identical(bits, variant):
    """The new stochastic kinds ride the same wire: a whole run_stochastic
    trajectory under the WK2 same-sample rule and/or svrg-corrected
    gradients (second backprops, anchor refresh cond, minibatch sampling in
    the loop) reproduces bitwise across wire backends."""
    key = jax.random.PRNGKey(3)
    kx, ky = jax.random.split(key)
    M, n_local, p = 6, 12, 8
    X = jax.random.normal(kx, (M, n_local, p))
    w_true = jnp.linspace(-1.0, 1.0, p)
    Yn = X @ w_true + 0.3 * jax.random.normal(ky, (M, n_local))

    def loss_fn(params, data):
        x, y = data
        return 0.5 * jnp.sum(jnp.square(x @ params["w"] - y)) / (M * n_local)

    p0 = {"w": jnp.zeros((p,))}
    kind = "slaq" if variant == "svrg" else "slaq_wk2"
    grad_mode = "sgd" if variant == "wk2" else "svrg"

    def run(backend):
        cfg = StrategyConfig(kind="laq", bits=bits,
                             criterion=CriterionConfig(D=10, xi=0.08, t_bar=20),
                             wire_backend=backend, grad_mode=grad_mode,
                             svrg_period=7)
        return run_stochastic(loss_fn, p0, (X, Yn), kind, steps=50,
                              alpha=0.3, batch=4, bits=bits, seed=2,
                              laq_cfg=cfg)

    rr, rf = run("reference"), run("fused")
    np.testing.assert_array_equal(np.asarray(rr.loss), np.asarray(rf.loss))
    np.testing.assert_array_equal(np.asarray(rr.cum_bits),
                                  np.asarray(rf.cum_bits))
    np.testing.assert_array_equal(np.asarray(rr.cum_uploads),
                                  np.asarray(rf.cum_uploads))
    np.testing.assert_array_equal(np.asarray(rr.params["w"]),
                                  np.asarray(rf.params["w"]))


@pytest.mark.parametrize("per_leaf", RADII)
@pytest.mark.parametrize("sched_kind", ["radius", "budget"])
def test_adaptive_bits_accounting_matches_across_backends(per_leaf, sched_kind):
    """Satellite fix: per-leaf radii mean ``n_sidecars = n_leaves`` f32
    sidecars in ``upload_bits``; the accounting lives in worker_update and
    must be backend-independent — both backends report identical bits_m,
    widths and cumulative totals through the adaptive path."""
    sched = BitSchedule(kind=sched_kind, thresholds=(0.05, 0.5),
                        total_bits=5e6, horizon=20)
    g = _tree()
    grads = jax.tree.map(lambda l: jnp.stack([l * (1 + 0.1 * w)
                                              for w in range(4)]), g)

    def run(backend):
        cfg = StrategyConfig(kind="laq", bits=4, per_leaf_radius=per_leaf,
                             criterion=CriterionConfig(D=10, xi=0.08, t_bar=100),
                             bit_schedule=sched, wire_backend=backend)
        st = init_comm_state(g, 4, cfg)
        outs = []
        for _ in range(3):
            agg, st, metrics = aggregate(st, grads, 0.1, cfg)
            outs.append((metrics.bits, metrics.mean_bits, st.bits_spent,
                         st.total_bits))
        return outs, agg, st

    (or_, agg_r, st_r) = run("reference")
    (of_, agg_f, st_f) = run("fused")
    for (br, wr, sr, tr), (bf, wf, sf, tf) in zip(or_, of_):
        np.testing.assert_array_equal(np.asarray(br), np.asarray(bf))
        np.testing.assert_array_equal(np.asarray(wr), np.asarray(wf))
        np.testing.assert_array_equal(np.asarray(sr), np.asarray(sf))
        np.testing.assert_array_equal(np.asarray(tr), np.asarray(tf))
    assert _trees_equal(agg_r, agg_f)
    assert _trees_equal(st_r.qhat, st_f.qhat)


@pytest.mark.parametrize("sel", range(len(GRID)))
@pytest.mark.parametrize("per_leaf", RADII)
def test_adaptive_roundtrip_bit_identical(sel, per_leaf):
    """Adaptive pass 2 through the backends at every pinned grid width:
    the staged reference sweep (quantize_dynamic/dequantize_dynamic) vs the
    fused one-sweep pipeline — q_new/delta bitwise, scalar moments to f32
    reduction accuracy (same contract as the fixed-width roundtrip)."""
    g, qh = _tree(), _qhat()
    onehot = jnp.eye(len(GRID), dtype=jnp.float32)[sel]

    def rt(backend):
        def f(g, qh):
            diff, R_tree, _ = innovation(g, qh, per_leaf)
            return get_backend(backend).adaptive_roundtrip(
                g, qh, diff, R_tree, GRID, onehot)
        return jax.jit(f)(g, qh)

    r, f = rt("reference"), rt("fused")
    assert _trees_equal(r[0], f[0]), "q_new differs across wire backends"
    assert _trees_equal(r[1], f[1]), "delta differs across wire backends"
    np.testing.assert_allclose(float(f[2]), float(r[2]), rtol=1e-6)
    np.testing.assert_allclose(float(f[3]), float(r[3]), rtol=1e-6)


# abs-mode threshold pairs that pin the radius schedule to each grid width
# for a whole run (R > both / between / below both), plus the natural
# schedule that walks down the grid as the innovation radius decays
_PIN_2 = (1e30, 2e30)
_PIN_4 = (1e-30, 1e30)
_PIN_8 = (1e-30, 2e-30)


@pytest.mark.parametrize("thresholds",
                         [_PIN_2, _PIN_4, _PIN_8, (0.05, 0.5)])
def test_adaptive_trajectory_bit_identical(thresholds):
    """A whole simulated adaptive run (bit_schedule selection + dynamic
    quantizer in the scan loop) reproduces identically whether pass 2 is
    the staged reference sweep or the fused kernel — at every pinned grid
    width and across the mixed-width natural schedule."""
    key = jax.random.PRNGKey(0)
    kc, ka = jax.random.split(key)
    M, p = 10, 20
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M

    p0 = {"x": jnp.zeros((p,))}
    sched = BitSchedule(kind="radius", grid=GRID, thresholds=thresholds)

    def run(backend):
        cfg = StrategyConfig(kind="laq", bits=4,
                             criterion=CriterionConfig(D=10, xi=0.08,
                                                       t_bar=100),
                             bit_schedule=sched, wire_backend=backend)
        return run_gradient_based(loss_fn, p0, (centers, scales), cfg,
                                  steps=120, alpha=0.3)

    rr, rf = run("reference"), run("fused")
    np.testing.assert_array_equal(np.asarray(rr.loss), np.asarray(rf.loss))
    np.testing.assert_array_equal(np.asarray(rr.cum_bits),
                                  np.asarray(rf.cum_bits))
    np.testing.assert_array_equal(np.asarray(rr.cum_uploads),
                                  np.asarray(rf.cum_uploads))
    np.testing.assert_array_equal(np.asarray(rr.params["x"]),
                                  np.asarray(rf.params["x"]))


@pytest.mark.parametrize("sel", range(len(GRID)))
def test_fused_adaptive_pallas_lowering_matches_jnp(sel):
    """The two lowerings of the adaptive fused pass 2 implement one
    algorithm: the interpret-mode width-grid-unrolled Pallas kernel vs the
    dense flat jnp sweep.  Same tolerance contract as the fixed-width
    lowering test."""
    g, qh = _tree(), _qhat()
    onehot = jnp.eye(len(GRID), dtype=jnp.float32)[sel]

    def rt(lowering):
        diff, R_tree, _ = innovation(g, qh, False)
        return FusedWire(lowering=lowering).adaptive_roundtrip(
            g, qh, diff, R_tree, GRID, onehot)

    j, p = rt("jnp"), rt("pallas")
    for a, b in zip(jax.tree.leaves(j[0]), jax.tree.leaves(p[0])):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)
    for a, b in zip(jax.tree.leaves(j[1]), jax.tree.leaves(p[1])):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)
    np.testing.assert_allclose(float(p[2]), float(j[2]), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(float(p[3]), float(j[3]), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("bits", BITS)
def test_fused_pallas_lowering_matches_jnp(bits):
    """The two lowerings of the fused backend implement one algorithm:
    interpret-mode Pallas (the TPU kernels) vs the blocked jnp expression.
    Codes are exact; floats to interpret-mode accuracy (no XLA mul-add
    contraction there)."""
    g, qh = _tree(), _qhat()
    jnp_rt = FusedWire(lowering="jnp").roundtrip(g, qh, bits, False,
                                                 with_payload=True)
    pls_rt = FusedWire(lowering="pallas").roundtrip(g, qh, bits, False,
                                                    with_payload=True)
    assert float(jnp_rt.R_max) == float(pls_rt.R_max)
    for a, b in zip(jax.tree.leaves(jnp_rt.delta), jax.tree.leaves(pls_rt.delta)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(float(jnp_rt.err_sq), float(pls_rt.err_sq),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(float(jnp_rt.innovation_sq),
                               float(pls_rt.innovation_sq), rtol=1e-4,
                               atol=1e-6)
    # payload layouts differ only in pad length: real code bytes agree
    cpb = 8 // bits
    for pj, pp, leaf in zip(jnp_rt.payload, pls_rt.payload,
                            jax.tree.leaves(g)):
        nbytes = leaf.size // cpb
        np.testing.assert_array_equal(np.asarray(pj[:nbytes]),
                                      np.asarray(pp[:nbytes]))


def test_dequant_acc_backends_match():
    W, n, bits = 4, 5000, 4
    key = jax.random.PRNGKey(1)
    packed = jax.random.randint(key, (W, 2560), 0, 256).astype(jnp.uint8)
    R = jax.random.uniform(jax.random.fold_in(key, 1), (W,))
    keep = jnp.array([1.0, 0.0, 1.0, 1.0])
    acc = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    ref = get_backend("reference").dequant_acc(packed, R, keep, bits, n, acc)
    fus = FusedWire(lowering="jnp").dequant_acc(packed, R, keep, bits, n, acc)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fus))


def test_get_backend():
    assert get_backend("fused").name == "fused"
    assert get_backend(FusedWire(lowering="jnp")).name == "fused"
    with pytest.raises(ValueError):
        get_backend("nope")


@pytest.mark.parametrize("bits", BITS)
def test_axis_pack_helpers_roundtrip(bits):
    key = jax.random.PRNGKey(2)
    q = jax.random.randint(key, (6, 16), 0, 2 ** bits).astype(jnp.uint8)
    payload = pack_codes_along_axis(q, bits)
    assert payload.shape[-1] == 16 * bits // 8
    np.testing.assert_array_equal(
        np.asarray(unpack_codes_along_axis(payload, bits, q)), np.asarray(q))
    odd = jax.random.randint(key, (5, 7), 0, 2 ** bits).astype(jnp.uint8)
    if bits == 8 or not axis_packable(odd, bits):
        # raw-code shipping path: identity both ways
        np.testing.assert_array_equal(
            np.asarray(pack_codes_along_axis(odd, bits)), np.asarray(odd))


# ---------------------------------------------------------------------------
# Sparse wire (EF-LAQ compressor pipeline) — the bit-identity contract
# extends to the sparse payload: selection/scatter/moments/packing are
# shared code, only the quantize stage's elementwise map is per-backend.
# ---------------------------------------------------------------------------

SPARSE_BITS = (1, 2, 4)
SPARSE_MODES = ("topk", "randk")


@pytest.mark.parametrize("mode", SPARSE_MODES)
@pytest.mark.parametrize("bits", SPARSE_BITS)
def test_sparse_roundtrip_bit_identical(mode, bits):
    from repro.core.wire import sparse_roundtrip
    g, qh = _tree(), _qhat()
    key = jax.random.PRNGKey(5)
    k = 173    # odd, not a multiple of codes-per-byte

    def rt(backend):
        return jax.jit(lambda g, qh: sparse_roundtrip(
            get_backend(backend), g, qh, bits, k, mode, key=key,
            with_payload=True))(g, qh)

    r, f = rt("reference"), rt("fused")
    np.testing.assert_array_equal(np.asarray(r.idx), np.asarray(f.idx))
    np.testing.assert_array_equal(np.asarray(r.codes), np.asarray(f.codes))
    np.testing.assert_array_equal(np.asarray(r.payload), np.asarray(f.payload))
    assert float(r.lo) == float(f.lo) and float(r.R) == float(f.R)
    assert _trees_equal(r.delta, f.delta)
    assert _trees_equal(r.q_new, f.q_new)
    np.testing.assert_array_equal(np.asarray(r.err_sq), np.asarray(f.err_sq))
    np.testing.assert_array_equal(np.asarray(r.innovation_sq),
                                  np.asarray(f.innovation_sq))


@pytest.mark.parametrize("bits", SPARSE_BITS)
def test_sparse_pallas_lowering_matches_reference(bits):
    """The interpret-mode Pallas sparse kernel (kernels/quant_pack.py)
    mirrors reference_sparse_quantize op-for-op: codes exact, dequantized
    values to interpret-mode float accuracy."""
    from repro.core.compressors import (reference_sparse_quantize,
                                        sparse_grid)
    from repro.kernels import sparse_quantize_pack
    vals = jax.random.normal(jax.random.PRNGKey(2), (397,)) * 1.7
    lo, hi = sparse_grid(vals, bits)
    rc, rd = reference_sparse_quantize(vals, lo, hi, bits)
    _, pc, pd = sparse_quantize_pack(vals, lo, hi, bits, interpret=True)
    np.testing.assert_array_equal(np.asarray(rc), np.asarray(pc))
    np.testing.assert_allclose(np.asarray(pd), np.asarray(rd), atol=1e-6)


@pytest.mark.parametrize("mode", SPARSE_MODES)
@pytest.mark.parametrize("ef", [False, True])
def test_sparse_worker_update_bit_identical(mode, ef):
    """The compressed worker state machine (masked delta, qhat, eps, bit
    accounting, and the EF residual commit) matches bitwise across wire
    backends."""
    from repro.core.compressors import ErrorState, compressor_keys
    g, qh = _tree(), _qhat()
    err = ErrorState(residual=jax.tree.map(
        lambda l: 0.01 * l, g)) if ef else ErrorState(None)
    ckey = compressor_keys(0, jnp.int32(3), 4)[1] if mode == "randk" else None
    theta_hist = jnp.full((10,), 0.3, jnp.float32)
    crit = CriterionConfig(D=10, xi=0.08, t_bar=100)

    def upd(backend):
        cfg = StrategyConfig(kind="laq", bits=2, criterion=crit,
                             wire_backend=backend, compressor=mode,
                             compressor_k=0.05, error_feedback=ef)
        return jax.jit(lambda g, qh: worker_update(
            g, qh, jnp.float32(0.05), jnp.int32(3), jnp.float32(0.0),
            theta_hist, 0.1, 10, cfg, error_m=err, ckey_m=ckey))(g, qh)

    r, f = upd("reference"), upd("fused")
    names = ("delta_masked", "qhat_new", "eps_hat_sq", "clock", "uploaded",
             "bits_m", "R", "width", "lazy", "R_anchor", "error_new")
    for name, a, b in zip(names, r, f):
        assert _trees_equal(a, b), f"{name} differs across wire backends"


@pytest.mark.parametrize("mode", SPARSE_MODES)
@pytest.mark.parametrize("bits", (1, 2))
def test_sparse_trajectory_bit_identical(mode, bits):
    """A whole simulated EF-LAQ run (compressor pipeline + error memory +
    skip criterion in the scan loop) reproduces identically on either
    backend."""
    key = jax.random.PRNGKey(0)
    kc, ka = jax.random.split(key)
    M, p = 8, 24
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M

    p0 = {"x": jnp.zeros((p,))}

    def run(backend):
        cfg = StrategyConfig(kind="laq", bits=bits,
                             criterion=CriterionConfig(D=10, xi=0.08,
                                                       t_bar=100),
                             wire_backend=backend, compressor=mode,
                             compressor_k=0.25, error_feedback=True)
        return run_gradient_based(loss_fn, p0, (centers, scales), cfg,
                                  steps=100, alpha=0.1)

    rr, rf = run("reference"), run("fused")
    np.testing.assert_array_equal(np.asarray(rr.loss), np.asarray(rf.loss))
    np.testing.assert_array_equal(np.asarray(rr.cum_bits),
                                  np.asarray(rf.cum_bits))
    np.testing.assert_array_equal(np.asarray(rr.cum_uploads),
                                  np.asarray(rf.cum_uploads))
    np.testing.assert_array_equal(np.asarray(rr.params["x"]),
                                  np.asarray(rf.params["x"]))
