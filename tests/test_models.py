"""Per-architecture smoke tests (reduced same-family variants) + numerics:
chunked attention vs naive softmax, SSD scan vs naive recurrence, MoE
capacity path vs dense reference, prefill/decode consistency, and
LAQ-train-step integration smokes (dense/mamba2/moe on the 8-device mesh
with exact wire-bit accounting)."""
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import (decode_step, forward, init_params,
                          lm_loss, n_params, prefill)
from repro.models.attention import chunked_causal_attention
from repro.models.config import ModelConfig
from repro.models.mamba2 import ssd_chunked
from repro.models.moe import init_moe, moe_forward_capacity, moe_forward_dense


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward + one SGD train step on the reduced config: shapes + no NaN."""
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    assert sum(l.size for l in jax.tree.leaves(params)) == n_params(cfg)
    B, S = 2, 64
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    logits, aux = forward(params, tokens, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    loss, grads = jax.value_and_grad(lm_loss)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert math.isfinite(gn) and gn > 0, f"{arch}: bad grads"
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = lm_loss(new, batch, cfg)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_serve(arch):
    """Prefill then 3 decode steps; last-prompt-token logits must match the
    training forward exactly."""
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, tokens, cfg)
    last, cache = prefill(params, tokens, cfg, max_len=S + 8)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full_logits[:, -1]),
                               atol=2e-2, rtol=1e-2)
    for i in range(3):
        nxt = jnp.argmax(last[:, -1:], -1).astype(jnp.int32)
        nxt = jnp.clip(nxt, 0, cfg.vocab - 1)
        last, cache = decode_step(params, cache, nxt, cfg)
        assert bool(jnp.isfinite(last).all())
    assert int(cache["pos"]) == S + 3


def test_decode_equals_teacher_forcing():
    """Decode logits at position t must match the full forward at t."""
    cfg = smoke_config(get_config("stablelm-1.6b"))
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, S = 1, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, tokens, cfg)
    _, cache = prefill(params, tokens[:, :8], cfg, max_len=S)
    for t in range(8, S):
        logits, cache = decode_step(params, cache, tokens[:, t:t + 1], cfg)
        if t < S - 1:
            np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                       np.asarray(full_logits[:, t]),
                                       atol=3e-2, rtol=2e-2)


def test_chunked_attention_matches_naive():
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=64,
                      vocab=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=64, q_chunk=16, kv_chunk=8)
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), jnp.float32)
    pos = jnp.arange(S)
    out = chunked_causal_attention(q, k, v, pos, pos, cfg)
    # naive reference
    kk = jnp.repeat(k, H // KV, axis=2)
    vv = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    mask = pos[:, None] >= pos[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_sliding_window_attention():
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=64,
                      vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                      d_ff=64, q_chunk=16, kv_chunk=8, sliding_window=8)
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 1, 32, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    pos = jnp.arange(S)
    out = chunked_causal_attention(q, k, v, pos, pos, cfg)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = (pos[:, None] >= pos[None, :]) & (pos[:, None] - pos[None, :] < 8)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_ssd_chunked_matches_naive_recurrence():
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 32, 3, 8, 5
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (B, S, N), jnp.float32)
    y, state = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)

    # naive per-step recurrence
    s = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    xn, dtn, An, Bn, Cn = map(np.asarray, (x, dt, A, Bm, Cm))
    for t in range(S):
        dA = np.exp(dtn[:, t] * An)                       # [B,H]
        outer = np.einsum("bh,bn,bhp->bhnp", dtn[:, t], Bn[:, t], xn[:, t])
        s = s * dA[..., None, None] + outer
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], s)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.transpose(s, (0, 1, 3, 2)),
                               atol=1e-3, rtol=1e-3)


def test_moe_capacity_matches_dense_when_uncapped():
    """With capacity_factor large enough for zero drops the capacity path must
    equal the dense all-experts reference."""
    cfg = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=32,
                      vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                      n_experts=4, top_k=2, moe_d_ff=16,
                      capacity_factor=4.0, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32), jnp.float32)
    yc, aux_c = moe_forward_capacity(p, x, cfg)
    yd, aux_d = moe_forward_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yd), atol=1e-4)
    np.testing.assert_allclose(float(aux_c), float(aux_d), rtol=1e-5)


def test_moe_scatter_combine_matches_gather():
    import dataclasses
    cfg = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=32,
                      vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                      n_experts=4, top_k=2, moe_d_ff=16,
                      capacity_factor=4.0, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    yg, _ = moe_forward_capacity(p, x, cfg)
    ys, _ = moe_forward_capacity(
        p, x, dataclasses.replace(cfg, moe_combine="scatter"))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys), atol=1e-5)


def test_moe_capacity_drops_overflow():
    """With capacity_factor << 1 the output must stay finite (drops are zeros)."""
    cfg = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=32,
                      vocab=64, n_experts=4, top_k=2, moe_d_ff=16,
                      n_heads=2, n_kv_heads=2, capacity_factor=0.25,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    yc, _ = moe_forward_capacity(p, x, cfg)
    assert bool(jnp.isfinite(yc).all())


def test_moe_router_aux_flows_through_accumulated_gradient():
    """The router's load-balance aux loss must reach the router weights
    through the gradient-accumulation fold (core/engine.py
    accumulate_loss_grads) — an aux-only objective folded over microbatches
    yields nonzero router gradients."""
    from repro.core.engine import accumulate_loss_grads
    from repro.models.model import AUX_LOSS_WEIGHT

    cfg = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=32,
                      vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                      n_experts=4, top_k=2, moe_d_ff=16, q_chunk=16,
                      kv_chunk=8, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 16), 0, cfg.vocab)
    mbs = {"tokens": tokens, "targets": jnp.roll(tokens, -1, -1)}

    def aux_only(p, b):
        _, aux = forward(p, b["tokens"], cfg)
        return AUX_LOSS_WEIGHT * aux

    loss, grads = accumulate_loss_grads(aux_only, params, mbs)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    paths, _ = jax.tree_util.tree_flatten_with_path(grads)
    router = [leaf for path, leaf in paths
              if "router" in jax.tree_util.keystr(path)]
    assert router, "no router leaves in the gradient tree"
    assert max(float(jnp.max(jnp.abs(g))) for g in router) > 0.0, \
        "aux loss did not reach the router through the accumulation fold"
    # the full LM objective (ce + aux) stays finite through the same fold
    full, _ = accumulate_loss_grads(lambda p, b: lm_loss(p, b, cfg),
                                    params, mbs)
    assert bool(jnp.isfinite(full))


_LAQ_ARCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config
from repro.core.strategy import StrategyConfig
from repro.optim import sgd
from repro.launch.train import (make_train_step, train_state_specs,
                                init_train_state)
from repro.data import synthetic_lm_batch

out = {}
strategy = StrategyConfig(kind="laq", bits=4, per_leaf_radius=True)
opt = sgd()
mesh = jax.make_mesh((4, 2), ("data", "model"))
wa = ("data",)
# moe runs with microbatch=2: the sharded step folds the round's gradient
# (aux loss included) through accumulate_loss_grads
for arch, accum in (("yi-6b", 1), ("mamba2-130m", 1),
                    ("qwen3-moe-30b-a3b", 2)):
    cfg = smoke_config(get_config(arch))
    state = init_train_state(jax.random.PRNGKey(0), cfg, mesh, strategy,
                             opt, wa)
    specs = train_state_specs(cfg, mesh, strategy, opt, wa)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                         state, specs)
    batch = synthetic_lm_batch(jax.random.PRNGKey(1), 8, 64, cfg.vocab)
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    step = jax.jit(make_train_step(cfg, mesh, strategy, opt, lr=1e-2,
                                   worker_axes=wa, wire="float",
                                   microbatch=accum))
    state, m = step(state, batch)
    out[arch] = {
        "loss": float(m.loss),
        "uploads": int(m.uploads),
        "total_bits": float(state.comm.total_bits),
        "p": int(sum(x.size for x in jax.tree.leaves(state.params))),
        "n_leaves": len(jax.tree.leaves(state.params)),
    }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_laq_step_arch_smokes_subprocess():
    """One LAQ round per architecture family (dense / mamba2 / moe) on the
    (4 data x 2 model) 8-device mesh: loss finite, and the wire accounting
    is exact against the hand-computed first-round cohort — all W workers
    upload (first_round_upload), each paying upload_bits(p, 4,
    n_radii=n_leaves) since per_leaf_radius exchanges one f32 radius per
    leaf."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _LAQ_ARCH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    W = 4
    for arch, o in out.items():
        assert math.isfinite(o["loss"]), (arch, o)
        assert o["uploads"] == W, (arch, o)
        expected = W * (32 * o["n_leaves"] + 4 * o["p"])
        assert o["total_bits"] == float(expected), (arch, o, expected)


def test_moe_router_legacy_fallback_matches_top_k(monkeypatch):
    """The 0.4.x in-region router fallback (K argmax+mask rounds, since
    top_k's sort aborts the legacy partial-auto partitioner) selects the
    SAME experts with the SAME weights as jax.lax.top_k — bitwise,
    including the uniform-probs tie case (both break ties toward the lower
    index)."""
    from repro import compat
    from repro.models.moe import _router
    cfg = smoke_config(get_config("qwen3-moe-30b-a3b"))
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (2, 16, cfg.d_model), jnp.float32)
    x = x.at[0, 0].set(0.0)   # uniform-probs row: exercises tie-breaking
    p = {"router": jax.random.normal(k2, (cfg.d_model, cfg.n_experts),
                                     jnp.float32)}
    native = _router(p, x, cfg)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    monkeypatch.setattr(compat, "ON_LEGACY_JAX", True)
    with compat._ambient(mesh):
        assert compat.in_legacy_partial_auto_region()
        legacy = _router(p, x, cfg)
    assert not compat.in_legacy_partial_auto_region()
    for a, b in zip(native, legacy):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_long_context_configs():
    """for_shape applies the sliding window to attention archs at long_500k."""
    from repro.configs import for_shape
    from repro.models.config import INPUT_SHAPES
    shp = INPUT_SHAPES["long_500k"]
    dense = for_shape(get_config("qwen3-8b"), shp)
    assert dense.sliding_window == 8192
    ssm = for_shape(get_config("mamba2-130m"), shp)
    assert ssm.sliding_window == 0          # recurrent: native long context
    hyb = for_shape(get_config("zamba2-2.7b"), shp)
    assert hyb.sliding_window == 8192       # shared attn block needs the ring
