"""compat.py version-gate coverage: the capability flags, the degradation
selectors that consult them (``needs_loop_unrolling``, ``exchange_mode``,
``resolve_wire_backend``), and ``warn_once`` semantics.

These tests run on BOTH CI jax pins (0.4.37 and latest): assertions are
written against ``compat.ON_LEGACY_JAX`` rather than a hardcoded side, and
the policy helpers are additionally exercised on the *other* side via
monkeypatched capability flags — so each pin also covers the branch it
doesn't take natively.
"""
import logging

import jax
import numpy as np
import pytest

from repro import compat
from repro.core.strategy import StrategyConfig
from repro.launch.train import exchange_mode, resolve_wire_backend


def test_version_gate_coherence():
    """Every capability flag is the same migration gate: all True on
    >= 0.5 (the primary path), all False on the legacy partitioner."""
    assert compat.ON_LEGACY_JAX == (compat.JAX_VERSION < (0, 5))
    for flag in (compat.SUPPORTS_LOOPS_OVER_AUTO_AXES,
                 compat.SUPPORTS_PARTIAL_AUTO_COLLECTIVES,
                 compat.SUPPORTS_PALLAS_PARTIAL_AUTO):
        assert flag == (not compat.ON_LEGACY_JAX)


@pytest.mark.parametrize("raw, parsed", [
    ("0.4.37", (0, 4, 37)),
    ("0.5.0", (0, 5, 0)),
    ("0.5.0rc1", (0, 5, 0)),
    ("0.7", (0, 7)),
    ("1.0.dev123", (1, 0, 0)),
])
def test_parse_version(raw, parsed):
    assert compat._parse_version(raw) == parsed


def test_needs_loop_unrolling_flips_with_ambient_mesh():
    """False outside any shard_map region on every jax; inside a compat
    region it is True exactly on the legacy partitioner (>= 0.5 never
    unrolls)."""
    assert not compat.needs_loop_unrolling()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    with compat._ambient(mesh):
        assert compat.needs_loop_unrolling() == compat.ON_LEGACY_JAX
    assert not compat.needs_loop_unrolling()


def test_warn_once_emits_once(caplog):
    key = "test-compat-warn-once-key"
    compat._warned.discard(key)
    with caplog.at_level(logging.WARNING, logger="repro.compat"):
        assert compat.warn_once(key, "first notice") is True
        assert compat.warn_once(key, "second notice") is False
    assert [r.message for r in caplog.records] == ["first notice"]


def test_exchange_mode_native(monkeypatch):
    """>= 0.5 side: gather for W > 2, one permute swap for pod pairs."""
    monkeypatch.setattr(compat, "SUPPORTS_PARTIAL_AUTO_COLLECTIVES", True)
    assert exchange_mode(2) == "permute"
    assert exchange_mode(4) == "gather"
    assert exchange_mode(8) == "gather"


def test_exchange_mode_legacy_degrades_to_psum(monkeypatch):
    """0.4.x side: the partitioner lowers only psum in partial-auto regions,
    so every worker count takes the local-decode+psum transport."""
    monkeypatch.setattr(compat, "SUPPORTS_PARTIAL_AUTO_COLLECTIVES", False)
    for w in (2, 4, 8):
        assert exchange_mode(w) == "local_decode_psum"


def test_exchange_mode_matches_this_pin():
    """Un-patched: the selection this jax actually runs."""
    expect = "local_decode_psum" if compat.ON_LEGACY_JAX else "gather"
    assert exchange_mode(4) == expect


def test_resolve_wire_backend_reference_untouched(monkeypatch):
    """A reference request never warns and never changes, on either side."""
    for flag in (True, False):
        monkeypatch.setattr(compat, "SUPPORTS_PALLAS_PARTIAL_AUTO", flag)
        s = StrategyConfig(wire_backend="reference")
        assert resolve_wire_backend(s) is s


def test_resolve_wire_backend_honored_on_native(monkeypatch, caplog):
    """>= 0.5 side: the fused request is honored as-is (the historical
    silent ``_replace(wire_backend="reference")`` pin is gone)."""
    monkeypatch.setattr(compat, "SUPPORTS_PALLAS_PARTIAL_AUTO", True)
    s = StrategyConfig(wire_backend="fused")
    with caplog.at_level(logging.WARNING, logger="repro.compat"):
        assert resolve_wire_backend(s) is s
    assert not caplog.records


def test_resolve_wire_backend_legacy_downgrade_warns_once(monkeypatch,
                                                         caplog):
    """0.4.x side: fused downgrades to the bit-identical reference pipeline
    with a log notice — once per process, not per step."""
    monkeypatch.setattr(compat, "SUPPORTS_PALLAS_PARTIAL_AUTO", False)
    compat._warned.discard("sharded-wire-backend-downgrade")
    s = StrategyConfig(wire_backend="fused")
    with caplog.at_level(logging.WARNING, logger="repro.compat"):
        resolved = resolve_wire_backend(s)
        again = resolve_wire_backend(s)
    assert resolved.wire_backend == "reference"
    assert again.wire_backend == "reference"
    assert len(caplog.records) == 1
    assert "downgrades" in caplog.records[0].message
