"""Pallas kernel validation: interpret-mode vs the pure-jnp ref oracle,
swept over shapes / bits / dtypes, plus hypothesis property coverage."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dequant_acc, quantize_pack
from repro.kernels.quant_pack import BLOCK
from repro.kernels.ref import dequant_acc_ref, quantize_pack_ref


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n", [BLOCK, 2 * BLOCK, 3 * BLOCK + 17, 5000, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_pack_matches_ref(bits, n, dtype):
    key = jax.random.PRNGKey(n * bits)
    g = (jax.random.normal(key, (n,)) * 5).astype(dtype)
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,)).astype(dtype)
    diff = g.astype(jnp.float32) - qh.astype(jnp.float32)
    R = jnp.max(jnp.abs(diff))
    packed, delta = quantize_pack(g, qh, R, bits)
    pad = (-n) % BLOCK
    dpad = jnp.concatenate([diff, jnp.zeros((pad,))]) if pad else diff
    packed_ref, delta_ref = quantize_pack_ref(dpad, R, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(packed_ref))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(delta_ref[:n]),
                               atol=1e-5)
    # the LAQ error bound holds through the kernel
    tau = 1.0 / (2 ** bits - 1)
    assert float(jnp.max(jnp.abs(diff - delta))) <= float(tau * R) + 1e-5


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("W", [1, 2, 4, 16])
def test_dequant_acc_matches_ref(bits, W):
    n = 2 * BLOCK
    key = jax.random.PRNGKey(W)
    packed = jax.random.randint(key, (W, n * bits // 8), 0, 256).astype(jnp.uint8)
    R = jax.random.uniform(jax.random.fold_in(key, 1), (W,)) * 3
    keep = (jax.random.uniform(jax.random.fold_in(key, 2), (W,)) > 0.3).astype(jnp.float32)
    out = dequant_acc(packed, R, keep, bits, n)
    ref = dequant_acc_ref(packed, R, keep, bits, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_roundtrip_wire_identity():
    """send-side kernel -> receive-side kernel == float-mode innovation."""
    n, bits, W = BLOCK, 4, 3
    key = jax.random.PRNGKey(7)
    grads = [jax.random.normal(jax.random.fold_in(key, w), (n,)) for w in range(W)]
    qh = jnp.zeros((n,))
    payloads, Rs, deltas = [], [], []
    for g in grads:
        R = jnp.max(jnp.abs(g - qh))
        pk, dl = quantize_pack(g, qh, R, bits)
        payloads.append(pk); Rs.append(R); deltas.append(dl)
    acc = dequant_acc(jnp.stack(payloads), jnp.stack(Rs),
                      jnp.ones((W,)), bits, n)
    np.testing.assert_allclose(np.asarray(acc),
                               np.asarray(sum(deltas)), atol=1e-4)


@hypothesis.given(scale=st.floats(1e-3, 1e3), bits=st.sampled_from([2, 4, 8]))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_kernel_error_bound(scale, bits):
    key = jax.random.PRNGKey(int(scale * 1000) % 2**31)
    g = jax.random.normal(key, (BLOCK,)) * scale
    qh = jnp.zeros((BLOCK,))
    R = jnp.max(jnp.abs(g))
    _, delta = quantize_pack(g, qh, R, bits)
    tau = 1.0 / (2 ** bits - 1)
    assert float(jnp.max(jnp.abs(g - delta))) <= float(tau * R) * (1 + 1e-5) + 1e-6
