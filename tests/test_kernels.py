"""Pallas kernel validation: interpret-mode vs the pure-jnp ref oracle,
swept over shapes / bits / dtypes (incl. the fused-pipeline edge cases:
R == 0 blocks, non-BLOCK-multiple lengths through ops.py padding, and
single-worker dequant_acc), plus hypothesis property coverage."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_codes, tau
from repro.kernels import (absmax, dequant_acc, quantize_codes_adaptive,
                           quantize_codes_fused, quantize_pack,
                           quantize_pack_adaptive, quantize_pack_fused)
from repro.kernels.quant_pack import BLOCK
from repro.kernels.ref import (absmax_ref, dequant_acc_ref,
                               quantize_pack_adaptive_ref,
                               quantize_pack_fused_ref, quantize_pack_ref)

# non-BLOCK-multiple lengths exercise the ops.py pad + in-kernel moment
# masking; 1 and 3 exercise a single nearly-empty block
EDGE_SHAPES = [1, 3, 128, 5000, BLOCK, BLOCK + 1, 3 * BLOCK + 17]

GRID = (2, 4, 8)       # the bit_schedule width grid the adaptive kernel unrolls


def _onehot(sel):
    return jnp.eye(len(GRID), dtype=jnp.float32)[sel]


def _unpack(packed, bits, n):
    """First n codes from a packed byte vector (little-end-first lanes)."""
    p = np.asarray(packed)
    if bits == 8:
        return p[:n]
    cpb = 8 // bits
    codes = np.stack([(p >> (bits * j)) & ((1 << bits) - 1)
                      for j in range(cpb)], axis=-1).reshape(-1)
    return codes[:n]


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n", [BLOCK, 2 * BLOCK, 3 * BLOCK + 17, 5000, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_pack_matches_ref(bits, n, dtype):
    key = jax.random.PRNGKey(n * bits)
    g = (jax.random.normal(key, (n,)) * 5).astype(dtype)
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,)).astype(dtype)
    diff = g.astype(jnp.float32) - qh.astype(jnp.float32)
    R = jnp.max(jnp.abs(diff))
    packed, delta = quantize_pack(g, qh, R, bits)
    pad = (-n) % BLOCK
    dpad = jnp.concatenate([diff, jnp.zeros((pad,))]) if pad else diff
    packed_ref, delta_ref = quantize_pack_ref(dpad, R, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(packed_ref))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(delta_ref[:n]),
                               atol=1e-5)
    # the LAQ error bound holds through the kernel
    tau = 1.0 / (2 ** bits - 1)
    assert float(jnp.max(jnp.abs(diff - delta))) <= float(tau * R) + 1e-5


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("W", [1, 2, 4, 16])
def test_dequant_acc_matches_ref(bits, W):
    n = 2 * BLOCK
    key = jax.random.PRNGKey(W)
    packed = jax.random.randint(key, (W, n * bits // 8), 0, 256).astype(jnp.uint8)
    R = jax.random.uniform(jax.random.fold_in(key, 1), (W,)) * 3
    keep = (jax.random.uniform(jax.random.fold_in(key, 2), (W,)) > 0.3).astype(jnp.float32)
    out = dequant_acc(packed, R, keep, bits, n)
    ref = dequant_acc_ref(packed, R, keep, bits, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_roundtrip_wire_identity():
    """send-side kernel -> receive-side kernel == float-mode innovation."""
    n, bits, W = BLOCK, 4, 3
    key = jax.random.PRNGKey(7)
    grads = [jax.random.normal(jax.random.fold_in(key, w), (n,)) for w in range(W)]
    qh = jnp.zeros((n,))
    payloads, Rs, deltas = [], [], []
    for g in grads:
        R = jnp.max(jnp.abs(g - qh))
        pk, dl = quantize_pack(g, qh, R, bits)
        payloads.append(pk)
        Rs.append(R)
        deltas.append(dl)
    acc = dequant_acc(jnp.stack(payloads), jnp.stack(Rs),
                      jnp.ones((W,)), bits, n)
    np.testing.assert_allclose(np.asarray(acc),
                               np.asarray(sum(deltas)), atol=1e-4)


# ---------------------------------------------------------------------------
# Fused-pipeline kernels: pass-1 absmax, pass-2 moment side-outputs, and the
# accumulating receive side.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", EDGE_SHAPES)
def test_absmax_matches_ref(n):
    key = jax.random.PRNGKey(n)
    g = jax.random.normal(key, (n,)) * 7
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    assert float(absmax(g, qh)) == float(absmax_ref(g, qh))


def test_absmax_zero_innovation():
    g = jnp.full((2 * BLOCK + 5,), 3.25)
    assert float(absmax(g, g)) == 0.0


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n", EDGE_SHAPES)
def test_quantize_pack_fused_matches_ref(bits, n):
    """Moment side-outputs must cover exactly the n real elements — the pad
    tail dequantizes to a nonzero midpoint delta, so an unmasked kernel sum
    would be wrong for every non-BLOCK-multiple length here."""
    key = jax.random.PRNGKey(n * bits + 1)
    g = jax.random.normal(key, (n,)) * 4
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    R = absmax(g, qh)
    packed, delta, q_new, err_sq, inn_sq = quantize_pack_fused(g, qh, R, bits)
    packed_r, delta_r, qn_r, err_r, inn_r = quantize_pack_fused_ref(g, qh, R,
                                                                    bits)
    cpb = 8 // bits
    np.testing.assert_array_equal(np.asarray(packed[:n // cpb]),
                                  np.asarray(packed_r[:n // cpb]))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(delta_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(q_new), np.asarray(qn_r), atol=1e-5)
    np.testing.assert_allclose(float(err_sq), float(err_r), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(float(inn_sq), float(inn_r), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_pack_fused_zero_radius_block(bits):
    """R == 0 (zero innovation): midpoint codes, exactly zero delta and
    moments — the q_new recursion must be a no-op."""
    n = BLOCK + 9
    g = jnp.linspace(-1.0, 1.0, n)
    packed, delta, q_new, err_sq, inn_sq = quantize_pack_fused(
        g, g, jnp.zeros(()), bits)
    assert int(jnp.max(jnp.abs(delta) > 0)) == 0
    np.testing.assert_array_equal(np.asarray(q_new), np.asarray(g))
    assert float(err_sq) == 0.0 and float(inn_sq) == 0.0
    codes = np.asarray(packed[: n // (8 // bits)])
    mid = (2 ** bits) // 2
    expect = sum(mid << (bits * j) for j in range(8 // bits))
    assert (codes == expect).all()


# ---------------------------------------------------------------------------
# Adaptive (width-grid-unrolled) pass-2 kernel: one lax.switch arm per grid
# width, payload provisioned at max(grid).  Same edge cases as fixed-width.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sel", range(len(GRID)))
@pytest.mark.parametrize("n", EDGE_SHAPES)
def test_quantize_pack_adaptive_matches_ref(sel, n):
    """Every grid width, incl. non-BLOCK-multiple lengths through the
    ops.py pad + in-kernel moment masking."""
    key = jax.random.PRNGKey(n * (sel + 1) + 2)
    g = jax.random.normal(key, (n,)) * 4
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    R = absmax(g, qh)
    out = quantize_pack_adaptive(g, qh, R, _onehot(sel), GRID)
    ref = quantize_pack_adaptive_ref(g, qh, R, GRID, sel)
    packed, delta, q_new, err_sq, inn_sq = out
    packed_r, delta_r, qn_r, err_r, inn_r = ref
    cpb = 8 // max(GRID)
    np.testing.assert_array_equal(np.asarray(packed[:n // cpb]),
                                  np.asarray(packed_r[:n // cpb]))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(delta_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(q_new), np.asarray(qn_r), atol=1e-5)
    np.testing.assert_allclose(float(err_sq), float(err_r), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(float(inn_sq), float(inn_r), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("sel", range(len(GRID)))
@pytest.mark.parametrize("n", [128, 5000, BLOCK + 1])
def test_quantize_pack_adaptive_matches_fixed_kernel(sel, n):
    """BITWISE anchor: the switch arm at a pinned width IS the fixed-width
    kernel pipeline — delta/q_new/moments exactly equal, codes equal after
    unpacking each payload at its own lane width."""
    bits = GRID[sel]
    key = jax.random.PRNGKey(n + sel)
    g = jax.random.normal(key, (n,)) * 4
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    R = absmax(g, qh)
    packed_a, delta_a, qn_a, err_a, inn_a = quantize_pack_adaptive(
        g, qh, R, _onehot(sel), GRID)
    packed_f, delta_f, qn_f, err_f, inn_f = quantize_pack_fused(g, qh, R, bits)
    np.testing.assert_array_equal(_unpack(packed_a, max(GRID), n),
                                  _unpack(packed_f, bits, n))
    np.testing.assert_array_equal(np.asarray(delta_a), np.asarray(delta_f))
    np.testing.assert_array_equal(np.asarray(qn_a), np.asarray(qn_f))
    assert float(err_a) == float(err_f)
    assert float(inn_a) == float(inn_f)


@pytest.mark.parametrize("sel", range(len(GRID)))
def test_quantize_pack_adaptive_zero_radius_block(sel):
    """R == 0: midpoint codes at the SELECTED width, exactly zero delta and
    moments — the q_new recursion must be a no-op."""
    n = BLOCK + 9
    g = jnp.linspace(-1.0, 1.0, n)
    packed, delta, q_new, err_sq, inn_sq = quantize_pack_adaptive(
        g, g, jnp.zeros(()), _onehot(sel), GRID)
    assert int(jnp.max(jnp.abs(delta) > 0)) == 0
    np.testing.assert_array_equal(np.asarray(q_new), np.asarray(g))
    assert float(err_sq) == 0.0 and float(inn_sq) == 0.0
    mid = (2 ** GRID[sel]) // 2
    assert (_unpack(packed, max(GRID), n) == mid).all()


@pytest.mark.parametrize("sel", range(len(GRID)))
@pytest.mark.parametrize("n", [3, 5000, BLOCK + 1])
def test_quantize_codes_adaptive_matches_fixed(sel, n):
    """The unpacked codes sweep (streamed sharded wire): adaptive == the
    fixed-width sweep at the pinned width, codes exactly."""
    bits = GRID[sel]
    key = jax.random.PRNGKey(n + 11 * sel)
    g = jax.random.normal(key, (n,)) * 3
    qh = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    R = absmax(g, qh)
    codes_a, delta_a = quantize_codes_adaptive(g, qh, R, _onehot(sel), GRID)
    codes_f, delta_f = quantize_codes_fused(g, qh, R, bits)
    np.testing.assert_array_equal(np.asarray(codes_a), np.asarray(codes_f))
    np.testing.assert_array_equal(np.asarray(delta_a), np.asarray(delta_f))
    # and both against the reference expressions
    d = g.astype(jnp.float32) - qh.astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(codes_f),
                                  np.asarray(quantize_codes(d, R, bits)))
    t = tau(bits)
    delta_ref = jnp.where(R > 0, 2.0 * t * R * codes_f.astype(jnp.float32) - R,
                          0.0)
    np.testing.assert_allclose(np.asarray(delta_f), np.asarray(delta_ref),
                               atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("W", [1, 3])
@pytest.mark.parametrize("n", [5000, 2 * BLOCK])
def test_dequant_acc_with_accumulator(bits, W, n):
    """Optional server-aggregate fold-in (one pass) == separate add; W=1
    covers the single-worker (per-pod) wire."""
    key = jax.random.PRNGKey(W * bits + n)
    npad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    packed = jax.random.randint(key, (W, npad * bits // 8), 0, 256).astype(jnp.uint8)
    R = jax.random.uniform(jax.random.fold_in(key, 1), (W,)) * 2
    keep = (jax.random.uniform(jax.random.fold_in(key, 2), (W,)) > 0.3).astype(jnp.float32)
    acc = jax.random.normal(jax.random.fold_in(key, 3), (n,))
    fused = dequant_acc(packed, R, keep, bits, n, acc)
    ref = dequant_acc_ref(packed, R, keep, bits, n, acc)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), atol=1e-4)
    two_pass = acc + dequant_acc(packed, R, keep, bits, n)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_pass),
                               atol=1e-4)


def test_dequant_acc_single_worker_zero_radius():
    """W=1 with R == 0: the worker's payload decodes to exactly zero, so
    the accumulator passes through untouched."""
    n = BLOCK
    packed = jnp.full((1, n // 2), 0x77, jnp.uint8)
    acc = jnp.arange(n, dtype=jnp.float32)
    out = dequant_acc(packed, jnp.zeros((1,)), jnp.ones((1,)), 4, n, acc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))


@hypothesis.given(scale=st.floats(1e-3, 1e3), bits=st.sampled_from([2, 4, 8]))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_kernel_error_bound(scale, bits):
    key = jax.random.PRNGKey(int(scale * 1000) % 2**31)
    g = jax.random.normal(key, (BLOCK,)) * scale
    qh = jnp.zeros((BLOCK,))
    R = jnp.max(jnp.abs(g))
    _, delta = quantize_pack(g, qh, R, bits)
    tau = 1.0 / (2 ** bits - 1)
    assert float(jnp.max(jnp.abs(g - delta))) <= float(tau * R) * (1 + 1e-5) + 1e-6
