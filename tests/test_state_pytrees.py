"""Property tests: ``LazyState`` / ``SvrgState`` / ``CommState`` pytree
round-trips.

The sharded launch path moves the whole ``CommState`` through
``jax.tree.map`` / flatten-unflatten boundaries (shard_map in/out specs,
``_squeeze0``/``_unsqueeze0``, device_put against spec trees).  Those
boundaries silently *drop* anything the pytree protocol does not carry —
exactly the failure mode rule-gated ``None`` fields invite.  These
hypothesis properties pin the contract:

* flatten → unflatten reconstructs the state bit-identically for every
  (lazy_rule x grad_mode) combination;
* ``None`` gating is structural: the treedef of a ``lasg_wk`` state differs
  from a ``laq7a`` state, so a mixed ``tree.map`` fails loudly instead of
  zipping mismatched leaves;
* the svrg anchor initializes to the *template values* (the initial
  iterate) and survives a worker-dim squeeze/unsqueeze round-trip — the
  per-shard view the sharded step takes.

The ``hypothesis`` import resolves to the deterministic fallback in
``conftest.py`` when the real package is absent (offline container).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import StrategyConfig, init_comm_state
from repro.core.lazy_rules import LAZY_RULES, LazyState, init_lazy_state
from repro.core.strategy import SvrgState, init_svrg_state

GRAD_MODES = ("sgd", "svrg")


def template(shape_a, shape_b):
    return {"w": jnp.arange(int(np.prod(shape_a)), dtype=jnp.float32)
                    .reshape(shape_a) * 0.25 - 1.0,
            "b": jnp.ones(shape_b, jnp.float32) * 3.0}


def cfg_for(rule, grad_mode):
    return StrategyConfig(kind="laq", bits=4, lazy_rule=rule,
                          grad_mode=grad_mode)


def assert_trees_bit_identical(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=20)
@given(rule=st.sampled_from(LAZY_RULES),
       grad_mode=st.sampled_from(GRAD_MODES),
       n_workers=st.integers(min_value=1, max_value=8),
       d0=st.integers(min_value=1, max_value=5),
       d1=st.integers(min_value=1, max_value=5))
def test_comm_state_flatten_unflatten_roundtrip(rule, grad_mode, n_workers,
                                                d0, d1):
    state = init_comm_state(template((d0, d1), (d1,)), n_workers,
                            cfg_for(rule, grad_mode))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert_trees_bit_identical(state, rebuilt)
    # identity tree.map is the shard_map spec-attachment shape: it must
    # preserve every leaf and every None gate
    mapped = jax.tree.map(lambda x: x, state)
    assert_trees_bit_identical(state, mapped)


@settings(max_examples=15)
@given(rule=st.sampled_from(LAZY_RULES),
       n_workers=st.integers(min_value=1, max_value=6))
def test_lazy_state_rule_gated_fields(rule, n_workers):
    tmpl = template((3, 4), (4,))
    lz = init_lazy_state(rule, tmpl, n_workers)
    assert isinstance(lz, LazyState)
    assert (lz.grad_ema is not None) == (rule == "lasg_wk")
    assert (lz.theta_last is not None) == (rule in ("lasg_wk2", "lasg_ps"))
    # scalar estimator fields always exist, shaped [W]
    assert lz.stat_ema.shape == (n_workers,)
    assert lz.sigma_hat_sq.shape == (n_workers,)
    if lz.theta_last is not None:
        # snapshot of the template VALUES (the initial iterate), per worker
        assert lz.theta_last["w"].shape == (n_workers,) + tmpl["w"].shape
        for m in range(n_workers):
            np.testing.assert_array_equal(
                np.asarray(lz.theta_last["w"][m]), np.asarray(tmpl["w"]))


@settings(max_examples=15)
@given(grad_mode=st.sampled_from(GRAD_MODES),
       n_workers=st.integers(min_value=1, max_value=6))
def test_svrg_state_anchor_gating_and_values(grad_mode, n_workers):
    tmpl = template((2, 3), (3,))
    sv = init_svrg_state(grad_mode, tmpl, n_workers)
    assert isinstance(sv, SvrgState)
    if grad_mode == "sgd":
        assert sv.theta_anchor is None and sv.mu_anchor is None
        # an sgd-mode state flattens to NO svrg leaves at all
        assert jax.tree_util.tree_leaves(sv) == []
        return
    assert sv.theta_anchor["b"].shape == (n_workers,) + tmpl["b"].shape
    for m in range(n_workers):
        np.testing.assert_array_equal(np.asarray(sv.theta_anchor["w"][m]),
                                      np.asarray(tmpl["w"]))
    assert float(jnp.max(jnp.abs(sv.mu_anchor["w"]))) == 0.0


@settings(max_examples=10)
@given(rule=st.sampled_from(LAZY_RULES),
       grad_mode=st.sampled_from(GRAD_MODES))
def test_worker_dim_squeeze_unsqueeze_roundtrip(rule, grad_mode):
    """The per-shard view of the sharded step: squeeze the W=1 worker dim
    off every per-worker field, then restore it — bit-identical, None
    gates intact (this is launch/train.py's _squeeze0/_unsqueeze0)."""
    state = init_comm_state(template((4, 2), (2,)), 1,
                            cfg_for(rule, grad_mode))
    for sub in (state.lazy, state.svrg):
        squeezed = jax.tree.map(lambda x: jnp.squeeze(x, 0)
                                if x.ndim >= 1 else x, sub)
        restored = jax.tree.map(
            lambda s, o: jnp.broadcast_to(s[None] if s.ndim + 1 == o.ndim
                                          else s, o.shape), squeezed, sub)
        assert_trees_bit_identical(sub, restored)


def test_mixed_rule_tree_map_fails_loudly():
    """Structural None gating: zipping states of different rules in one
    tree.map must raise, never silently pair mismatched leaves."""
    tmpl = template((3, 3), (3,))
    s_wk = init_comm_state(tmpl, 2, cfg_for("lasg_wk", "sgd"))
    s_7a = init_comm_state(tmpl, 2, cfg_for("laq7a", "sgd"))
    with pytest.raises(ValueError):
        jax.tree.map(lambda a, b: a, s_wk, s_7a)
    s_vr = init_comm_state(tmpl, 2, cfg_for("laq7a", "svrg"))
    with pytest.raises(ValueError):
        jax.tree.map(lambda a, b: a, s_vr, s_7a)


def test_leaf_count_is_rule_and_mode_determined():
    """The flattened leaf count depends only on (rule, grad_mode) — a
    regression guard against fields accidentally becoming unhashable /
    non-leaf and vanishing from sharded exchanges."""
    tmpl = template((2, 2), (2,))
    counts = {}
    for rule in LAZY_RULES:
        for gm in GRAD_MODES:
            n = len(jax.tree_util.tree_leaves(
                init_comm_state(tmpl, 3, cfg_for(rule, gm))))
            counts[(rule, gm)] = n
    base = counts[("laq7a", "sgd")]
    tmpl_leaves = 2   # {"w", "b"}
    # WK adds grad_ema (one leaf per param leaf); WK2/PS add theta_last
    assert counts[("lasg_wk", "sgd")] == base + tmpl_leaves
    assert counts[("lasg_wk2", "sgd")] == base + tmpl_leaves
    assert counts[("lasg_ps", "sgd")] == base + tmpl_leaves
    # svrg adds theta_anchor + mu_anchor regardless of rule
    for rule in LAZY_RULES:
        assert counts[(rule, "svrg")] == counts[(rule, "sgd")] + 2 * tmpl_leaves


# ---------------------------------------------------------------------------
# ErrorState (EF-LAQ error memory) — same None-gating discipline.
# ---------------------------------------------------------------------------

def cfg_ef(error_feedback, compressor="topk"):
    return StrategyConfig(kind="laq", bits=2, compressor=compressor,
                          compressor_k=0.25, error_feedback=error_feedback)


@settings(max_examples=20)
@given(ef=st.booleans(),
       n_workers=st.integers(min_value=1, max_value=8),
       d0=st.integers(min_value=1, max_value=5),
       d1=st.integers(min_value=1, max_value=5))
def test_error_state_flatten_unflatten_roundtrip(ef, n_workers, d0, d1):
    from repro.core.compressors import ErrorState
    state = init_comm_state(template((d0, d1), (d1,)), n_workers, cfg_ef(ef))
    assert isinstance(state.error, ErrorState)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert_trees_bit_identical(state, rebuilt)
    mapped = jax.tree.map(lambda x: x, state)
    assert_trees_bit_identical(state, mapped)
    if ef:
        assert state.error.residual["w"].shape == (n_workers, d0, d1)
        assert float(jnp.max(jnp.abs(state.error.residual["w"]))) == 0.0
    else:
        assert state.error.residual is None
        assert jax.tree_util.tree_leaves(state.error) == []


def test_error_state_leaf_count_gating():
    """EF off adds ZERO leaves to the flattened CommState (goldens and
    sharded exchanges untouched); EF on adds one residual leaf per param
    leaf."""
    tmpl = template((2, 2), (2,))
    base = len(jax.tree_util.tree_leaves(
        init_comm_state(tmpl, 3, cfg_for("laq7a", "sgd"))))
    off = len(jax.tree_util.tree_leaves(
        init_comm_state(tmpl, 3, cfg_ef(False))))
    on = len(jax.tree_util.tree_leaves(
        init_comm_state(tmpl, 3, cfg_ef(True))))
    assert off == base
    assert on == base + 2       # tmpl has two leaves {"w", "b"}


def test_error_state_mixed_gate_tree_map_fails_loudly():
    tmpl = template((3, 3), (3,))
    s_on = init_comm_state(tmpl, 2, cfg_ef(True))
    s_off = init_comm_state(tmpl, 2, cfg_ef(False))
    with pytest.raises(ValueError):
        jax.tree.map(lambda a, b: a, s_on, s_off)


@settings(max_examples=10)
@given(n_workers=st.integers(min_value=1, max_value=5))
def test_error_state_worker_dim_squeeze_unsqueeze(n_workers):
    """The sharded per-shard view: squeeze the worker dim off the residual,
    restore it — bit-identical (launch/train.py's _squeeze0/_unsqueeze0
    path, which the EF threading rides)."""
    state = init_comm_state(template((4, 2), (2,)), 1, cfg_ef(True))
    sub = state.error
    squeezed = jax.tree.map(lambda x: jnp.squeeze(x, 0), sub)
    restored = jax.tree.map(lambda s, o: jnp.broadcast_to(s[None], o.shape),
                            squeezed, sub)
    assert_trees_bit_identical(sub, restored)
    _ = n_workers


# ---------------------------------------------------------------------------
# DefenseState (fault-tolerant aggregation) — same None-gating discipline.
# ---------------------------------------------------------------------------

def cfg_def(defense):
    from repro.core import DefenseConfig
    return StrategyConfig(kind="laq", bits=4,
                          defense=DefenseConfig(**defense))


def test_defense_state_leaf_count_gating():
    """An inactive DefenseConfig adds ZERO pytree leaves: undefended runs
    keep the exact pre-robustness CommState structure (golden-parity and
    sharded in/out specs depend on it)."""
    from repro.core import DefenseState, init_defense_state, DefenseConfig
    tmpl = template((2, 2), (2,))
    base = len(jax.tree_util.tree_leaves(init_comm_state(tmpl, 3, cfg_def({}))))
    off = len(jax.tree_util.tree_leaves(
        init_comm_state(tmpl, 3, cfg_def({"reconcile_crashes": False}))))
    assert off == base                      # reconcile needs no state
    for knobs in ({"validate": True}, {"gate_mult": 4.0}, {"clip_mult": 2.0}):
        n = len(jax.tree_util.tree_leaves(
            init_comm_state(tmpl, 3, cfg_def(knobs))))
        assert n == base + 3, knobs         # norm_ema + norm_count + rejects
    # inactive config produces the all-None state object
    assert init_defense_state(DefenseConfig(), 3) == DefenseState(None, None,
                                                                  None)


def test_defense_state_worker_dim_and_roundtrip():
    from repro.core import DefenseConfig, init_defense_state
    ds = init_defense_state(DefenseConfig(validate=True, gate_mult=4.0), 5)
    assert ds.norm_ema.shape == (5,) and ds.rejects.dtype == jnp.int32
    sq = jax.tree.map(lambda x: x[0], ds)
    assert sq.norm_ema.shape == ()
    un = jax.tree.map(lambda x: x[None], sq)
    assert un.norm_count.shape == (1,)
    leaves, treedef = jax.tree_util.tree_flatten(ds)
    assert_trees_bit_identical(ds, jax.tree_util.tree_unflatten(treedef,
                                                                leaves))
    # per-shard allocation (sharded init path)
    shard = init_defense_state(DefenseConfig(validate=True), 5,
                               worker_dim=False)
    assert shard.norm_ema.shape == ()


def test_defense_state_gating_is_structural():
    """Defended and undefended CommStates have different treedefs, so a
    mixed zip cannot silently pair mismatched leaves: any map that touches
    both sides fails loudly (the None rides through as the whole subtree,
    never as a fabricated zero)."""
    tmpl = template((3, 3), (3,))
    s_on = init_comm_state(tmpl, 2, cfg_def({"validate": True}))
    s_off = init_comm_state(tmpl, 2, cfg_def({}))
    assert (jax.tree_util.tree_structure(s_on)
            != jax.tree_util.tree_structure(s_off))
    with pytest.raises(TypeError):
        jax.tree.map(lambda a, b: a + b, s_on, s_off)
