"""Compressor-pipeline unit tests (core/compressors.py).

The stage contract and its degenerate corners: k=0 and k=p sparsification,
empty and scalar pytree leaves through the flatten boundary, rand-k key
determinism, the sign-magnitude grid's contraction property (the EF
convergence requirement), and the pack stage's exact byte round-trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import (CodePacker, CompressorPipeline,
                                    ErrorState, RandKSparsifier,
                                    SparseSelection, TopKSparsifier,
                                    UniformQuantizer, _flat, _unflat,
                                    compressor_keys, init_error_state,
                                    make_compressor, reference_sparse_quantize,
                                    scatter_selection, select_support,
                                    sparse_dequantize, sparse_grid, static_k)
from repro.core.wire import sparse_roundtrip

PACK_BITS = (1, 2, 4, 8)


def _vec(p=64, seed=0, scale=2.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (p,)) * scale


# ---------------------------------------------------------------------------
# static_k and support selection.
# ---------------------------------------------------------------------------

def test_static_k_bounds_and_rounding():
    assert static_k(0.0, 100) == 0
    assert static_k(1.0, 100) == 100
    assert static_k(0.25, 100) == 25
    assert static_k(0.006, 100) == 1      # round, not floor
    assert static_k(1.0, 0) == 0
    with pytest.raises(AssertionError):
        static_k(1.5, 10)


@pytest.mark.parametrize("mode", ["topk", "randk"])
def test_select_support_k0_and_kp(mode):
    v = _vec(32)
    key = jax.random.PRNGKey(7)
    empty = select_support(mode, v, 0, key)
    assert empty.idx.shape == (0,) and empty.vals.shape == (0,)
    # k >= p: identity support in ascending order, values untouched
    for k in (32, 50):
        full = select_support(mode, v, k, key)
        np.testing.assert_array_equal(np.asarray(full.idx), np.arange(32))
        np.testing.assert_array_equal(np.asarray(full.vals), np.asarray(v))


def test_topk_keeps_largest_magnitudes_sorted():
    v = jnp.array([0.1, -5.0, 0.2, 3.0, -0.3, 4.0])
    sel = select_support("topk", v, 3)
    np.testing.assert_array_equal(np.asarray(sel.idx), [1, 3, 5])
    np.testing.assert_array_equal(np.asarray(sel.vals), [-5.0, 3.0, 4.0])


def test_randk_same_key_same_support_different_key_differs():
    v = _vec(256)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    a = select_support("randk", v, 16, k1)
    b = select_support("randk", v, 16, k1)
    c = select_support("randk", v, 16, k2)
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    assert not np.array_equal(np.asarray(a.idx), np.asarray(c.idx))
    # values are the gathered coordinates, unscaled (biased by design)
    np.testing.assert_array_equal(np.asarray(a.vals),
                                  np.asarray(v)[np.asarray(a.idx)])


def test_compressor_keys_functional_derivation():
    """fold_in chain: per-(seed, step, worker) keys with no carried state —
    re-deriving gives identical keys; any coordinate change perturbs them."""
    a = compressor_keys(0, jnp.int32(5), 4)
    b = compressor_keys(0, jnp.int32(5), 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a),
                              np.asarray(compressor_keys(0, jnp.int32(6), 4)))
    assert not np.array_equal(np.asarray(a),
                              np.asarray(compressor_keys(1, jnp.int32(5), 4)))
    # distinct workers draw distinct supports
    assert len({tuple(np.asarray(x)) for x in a}) == 4


# ---------------------------------------------------------------------------
# Sign-magnitude quantize stage.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", PACK_BITS)
def test_sparse_quantize_dequantize_inverse(bits):
    vals = _vec(48, seed=3)
    lo, hi = sparse_grid(vals, bits)
    codes, deq = reference_sparse_quantize(vals, lo, hi, bits)
    np.testing.assert_array_equal(
        np.asarray(sparse_dequantize(codes, lo, hi, bits)), np.asarray(deq))
    # per-coordinate error bounded by half a grid step (b>1) / by |v| (b=1)
    L = max(2 ** (bits - 1) - 1, 1)
    step = (float(hi) - float(lo)) / L
    err = np.abs(np.asarray(vals) - np.asarray(deq))
    if bits > 1:
        assert err.max() <= step / 2 + 1e-6
    assert codes.dtype == jnp.uint8 and int(codes.max()) < 2 ** bits


def test_sign_magnitude_grid_is_contractive():
    """The EF convergence requirement: ||v - Q(v)||^2 < ||v||^2, including
    at b=1 where the grid collapses to the L2-optimal scaled sign (the
    dense zero-less eq. 5-6 grid does NOT have this property on small
    survivors — why the sparse wire uses its own grid)."""
    for bits in PACK_BITS:
        for seed in range(5):
            vals = _vec(64, seed=seed)
            lo, hi = sparse_grid(vals, bits)
            _, deq = reference_sparse_quantize(vals, lo, hi, bits)
            rho = float(jnp.sum((vals - deq) ** 2) / jnp.sum(vals ** 2))
            assert rho < 1.0, (bits, seed, rho)


def test_sparse_grid_degenerate_inputs():
    z = jnp.zeros((), jnp.float32)
    lo, hi = sparse_grid(jnp.zeros((0,), jnp.float32), 2)
    assert float(lo) == 0.0 and float(hi) == 0.0
    # constant-magnitude survivors: step == 0, codes collapse to mag 0
    vals = jnp.array([0.5, -0.5, 0.5])
    lo, hi = sparse_grid(vals, 4)
    assert float(lo) == float(hi) == 0.5
    codes, deq = reference_sparse_quantize(vals, lo, hi, 4)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(vals), rtol=1e-6)
    _ = z


# ---------------------------------------------------------------------------
# Pack stage and full pipeline.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", PACK_BITS)
@pytest.mark.parametrize("k", [0, 5, 8])
def test_codepacker_roundtrip(bits, k):
    rng = np.random.default_rng(bits * 10 + k)
    codes = jnp.asarray(rng.integers(0, 2 ** bits, size=k), jnp.uint8)
    idx = jnp.asarray(np.sort(rng.choice(64, size=k, replace=False)),
                      jnp.int32)
    packer = CodePacker(bits)
    ctx = {}
    payload = packer.compress(SparseSelection(idx, codes), ctx)
    out = packer.decompress(payload, ctx)
    np.testing.assert_array_equal(np.asarray(out.idx), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out.vals), np.asarray(codes))


@pytest.mark.parametrize("mode", ["topk", "randk"])
@pytest.mark.parametrize("bits", (1, 2, 4))
def test_pipeline_roundtrip_shapes_and_support(mode, bits):
    p, k = 96, 12
    v = _vec(p, seed=5)
    pipe = make_compressor(mode, k, bits)
    key = jax.random.PRNGKey(11)
    dense, wire, ctx = pipe.roundtrip(v, key=key)
    idx, packed = wire
    assert dense.shape == (p,)
    assert idx.shape == (k,) and packed.dtype == jnp.uint8
    # reconstruction is supported exactly on idx
    nz = np.nonzero(np.asarray(dense))[0]
    assert set(nz).issubset(set(np.asarray(idx).tolist()))
    # off-support coordinates are exactly zero
    mask = np.ones(p, bool)
    mask[np.asarray(idx)] = False
    assert np.all(np.asarray(dense)[mask] == 0.0)


def test_pipeline_k_equals_p_reduces_to_dense_quantize():
    """k=p: the sparsifier is the identity and the pipeline is just the
    sign-magnitude quantizer over the full vector."""
    p, bits = 40, 4
    v = _vec(p, seed=9)
    dense, _, _ = make_compressor("topk", p, bits).roundtrip(v)
    lo, hi = sparse_grid(v, bits)
    _, deq = reference_sparse_quantize(v, lo, hi, bits)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(deq))


def test_pipeline_k0_reconstructs_zeros():
    v = _vec(24)
    dense, (idx, packed), _ = make_compressor("topk", 0, 2).roundtrip(v)
    assert idx.shape == (0,)
    np.testing.assert_array_equal(np.asarray(dense), np.zeros(24))


def test_pipeline_runs_under_jit_and_vmap():
    p, k, bits = 64, 8, 2
    pipe = make_compressor("randk", k, bits)

    @jax.jit
    def rt(v, key):
        dense, (idx, packed), ctx = pipe.roundtrip(v, key=key)
        return dense, idx

    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    vs = jnp.stack([_vec(p, seed=s) for s in range(3)])
    dense, idx = jax.vmap(rt)(vs, keys)
    assert dense.shape == (3, p) and idx.shape == (3, k)


# ---------------------------------------------------------------------------
# Flatten boundary: empty and scalar leaves.
# ---------------------------------------------------------------------------

def test_flat_unflat_empty_and_scalar_leaves():
    tree = {"a": jnp.zeros((0,), jnp.float32),
            "b": jnp.asarray(3.5, jnp.float32),
            "c": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    flat, meta = _flat(tree)
    assert flat.shape == (7,)
    back = _unflat(flat, meta)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
        assert back[k].shape == tree[k].shape


@pytest.mark.parametrize("mode", ["topk", "randk"])
def test_sparse_roundtrip_empty_and_scalar_leaves(mode):
    """The worker_update integration point must survive pytrees with empty
    and scalar leaves (the flatten boundary the sharded path also takes)."""
    g = {"a": jnp.zeros((0,), jnp.float32),
         "b": jnp.asarray(1.25, jnp.float32),
         "w": _vec(37, seed=2)}
    qh = jax.tree.map(lambda l: 0.5 * l, g)
    rt = sparse_roundtrip("reference", g, qh, 2, 4, mode,
                          key=jax.random.PRNGKey(0))
    for name in ("q_new", "delta"):
        leaf_tree = getattr(rt, name)
        assert leaf_tree["a"].shape == (0,)
        assert leaf_tree["b"].shape == ()
        assert leaf_tree["w"].shape == (37,)
    assert rt.idx.shape == (4,) and rt.codes.shape == (4,)
    assert float(rt.innovation_sq) >= 0.0


def test_scatter_selection_round_trips_support():
    v = _vec(20)
    sel = select_support("topk", v, 6)
    dense = scatter_selection(sel, sel.vals, 20)
    np.testing.assert_array_equal(np.asarray(dense)[np.asarray(sel.idx)],
                                  np.asarray(sel.vals))
    assert float(jnp.sum(jnp.abs(dense))) == pytest.approx(
        float(jnp.sum(jnp.abs(sel.vals))), rel=1e-6)


# ---------------------------------------------------------------------------
# Error-feedback state gating.
# ---------------------------------------------------------------------------

def test_init_error_state_gating_and_shapes():
    tmpl = {"w": jnp.ones((3, 4)), "b": jnp.ones((4,))}
    off = init_error_state(False, tmpl, 5)
    assert isinstance(off, ErrorState) and off.residual is None
    assert jax.tree_util.tree_leaves(off) == []
    on = init_error_state(True, tmpl, 5)
    assert on.residual["w"].shape == (5, 3, 4)
    assert on.residual["b"].shape == (5, 4)
    assert float(jnp.max(jnp.abs(on.residual["w"]))) == 0.0
    solo = init_error_state(True, tmpl, 5, worker_dim=False)
    assert solo.residual["w"].shape == (3, 4)


def test_pipeline_init_state_stateless_stages():
    pipe = make_compressor("topk", 4, 2)
    assert isinstance(pipe, CompressorPipeline)
    assert pipe.init_state({"w": jnp.ones((2,))}, 3) == [None, None, None]
    names = [type(s).__name__ for s in pipe.stages]
    assert names == ["TopKSparsifier", "UniformQuantizer", "CodePacker"]
    assert isinstance(pipe.stages[0], TopKSparsifier)
    rpipe = make_compressor("randk", 4, 2)
    assert isinstance(rpipe.stages[0], RandKSparsifier)
    assert isinstance(rpipe.stages[1], UniformQuantizer)
