"""Behavioural tests for GD/QGD/LAG/LAQ on strongly convex problems."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CriterionConfig, StrategyConfig, run_gradient_based,
                        run_stochastic)


def quadratic_problem(M=10, p=20, seed=0):
    """f_m(x) = 0.5 (x-c_m)^T A_m (x-c_m): strongly convex, heterogeneous."""
    key = jax.random.PRNGKey(seed)
    kc, ka = jax.random.split(key)
    centers = jax.random.normal(kc, (M, p))
    scales = 0.5 + jax.random.uniform(ka, (M, p))     # diagonal A_m

    def loss_fn(params, data):
        c, a = data
        return 0.5 * jnp.sum(a * jnp.square(params["x"] - c)) / M
    params0 = {"x": jnp.zeros((p,))}
    return loss_fn, params0, (centers, scales)


def run(kind, steps=400, alpha=0.3, bits=6, xi=0.08):
    loss_fn, p0, data = quadratic_problem()
    cfg = StrategyConfig(kind=kind, bits=bits,
                         criterion=CriterionConfig(D=10, xi=xi, t_bar=100))
    return run_gradient_based(loss_fn, p0, data, cfg, steps=steps, alpha=alpha)


def test_gd_converges_linearly():
    r = run("gd")
    f_opt = float(r.loss[-1])
    # clamp: float noise near convergence can push resid epsilon-negative
    resid = np.maximum(np.asarray(r.loss[:200]) - f_opt, 1e-12)
    y = np.log(resid[5:80])          # early segment, well above float floor
    x = np.arange(y.size)
    slope = np.polyfit(x, y, 1)[0]
    assert slope < -0.01


def test_laq_matches_gd_accuracy():
    rg, rl = run("gd"), run("laq")
    assert abs(float(rg.loss[-1]) - float(rl.loss[-1])) < 1e-3
    assert float(rl.grad_norm_sq[-1]) < 1e-5


def test_laq_saves_rounds_and_bits():
    rg, rq, rl, rlaq = run("gd"), run("qgd"), run("lag"), run("laq")
    # rounds: lazy variants << dense variants (paper Fig. 4b)
    assert int(rlaq.cum_uploads[-1]) < 0.5 * int(rq.cum_uploads[-1])
    assert int(rl.cum_uploads[-1]) < 0.75 * int(rg.cum_uploads[-1])
    # bits: LAQ < LAG < GD and LAQ < QGD (paper Fig. 4c / Table 2)
    assert float(rlaq.cum_bits[-1]) < float(rl.cum_bits[-1])
    assert float(rlaq.cum_bits[-1]) < float(rq.cum_bits[-1])
    assert float(rq.cum_bits[-1]) < float(rg.cum_bits[-1])


def test_quantization_error_decays():
    """Paper Fig. 3: the radius R (hence quantization error) decays with k."""
    r = run("laq")
    early = float(np.mean(np.asarray(r.quant_err[5:30])))
    late = float(np.mean(np.asarray(r.quant_err[-30:])))
    assert late < 0.05 * early


def test_staleness_bound_enforced():
    """With t_bar = 5 every worker uploads at least once every 6 rounds."""
    loss_fn, p0, data = quadratic_problem()
    cfg = StrategyConfig(kind="laq", bits=6,
                         criterion=CriterionConfig(D=5, xi=0.1, t_bar=5))
    r = run_gradient_based(loss_fn, p0, data, cfg, steps=60, alpha=0.3)
    ups = np.asarray(r.cum_uploads)
    # in any window of 6 iterations there are >= M=10 uploads... too strict;
    # check the global rate: >= steps/(t_bar+1) per worker
    assert int(ups[-1]) >= 10 * (60 // 6)


def test_qgd_approaches_gd_with_many_bits():
    rg = run("gd", steps=200)
    rq = run("qgd", steps=200, bits=8)
    np.testing.assert_allclose(np.asarray(rq.loss[-1]), np.asarray(rg.loss[-1]),
                               rtol=1e-3)


@pytest.mark.parametrize("kind", ["sgd", "qsgd", "ssgd", "slaq"])
def test_stochastic_variants_run_and_learn(kind):
    loss_fn, p0, data = quadratic_problem()
    # stochastic driver samples rows of worker data; reuse centers as 'samples'
    M, p = 10, 20
    key = jax.random.PRNGKey(3)
    X = jax.random.normal(key, (M, 50, p)) + jnp.arange(M)[:, None, None] * 0.1

    def sloss(params, xs):
        return 0.5 * jnp.mean(jnp.sum(jnp.square(params["x"] - xs), -1)) / M

    r = run_stochastic(sloss, {"x": jnp.zeros((p,))}, X, kind,
                       steps=150, alpha=0.05, batch=10, bits=4,
                       laq_cfg=StrategyConfig(kind="laq", bits=4,
                                              criterion=CriterionConfig(D=10, xi=0.08, t_bar=50)))
    # compare the *reducible* part: the within-cluster variance floor of the
    # quadratic is ~p/2/M and is most of loss0
    opt = float(sum(sloss({"x": jnp.mean(X.reshape(-1, p), 0)}, X[m])
                    for m in range(M)))
    gap0 = float(r.loss[0]) - opt
    gapK = float(r.loss[-1]) - opt
    assert gapK < 0.35 * gap0, (gapK, gap0, opt)
    assert np.isfinite(float(r.loss[-1]))
