"""Unit + property tests for the innovation quantizer (paper eq. 5-6)."""
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (dequantize_innovation, quantize_innovation,
                        quantize_roundtrip, tau, tree_inf_norm, tree_sq_norm,
                        pack_nibbles, unpack_nibbles, upload_bits, dense_bits)


def _tree(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, s) * (i + 1)
            for i, (k, s) in enumerate(zip(ks, shapes))}


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("per_leaf", [False, True])
def test_roundtrip_error_bound(bits, per_leaf):
    """Paper Fig. 1 guarantee: ||grad - Q(grad)||_inf <= tau * R."""
    key = jax.random.PRNGKey(0)
    g = _tree(key, [(64,), (8, 16), (3, 5, 7)])
    qh = jax.tree.map(jnp.zeros_like, g)
    q_new, delta, R_max, err_sq = quantize_roundtrip(g, qh, bits, per_leaf)
    qints, R_tree = quantize_innovation(g, qh, bits, per_leaf)
    for leaf_g, leaf_q, leaf_R in zip(jax.tree.leaves(g), jax.tree.leaves(q_new),
                                      jax.tree.leaves(R_tree)):
        err = jnp.max(jnp.abs(leaf_g - leaf_q))
        assert err <= tau(bits) * leaf_R + 1e-5


@pytest.mark.parametrize("bits", [3, 4, 8])
def test_server_recovery(bits):
    """Server reconstructs Q_m(theta^k) = qhat + dequant(codes, R)."""
    key = jax.random.PRNGKey(1)
    g = _tree(key, [(32,), (4, 4)])
    qh = _tree(jax.random.PRNGKey(2), [(32,), (4, 4)])
    qints, R_tree = quantize_innovation(g, qh, bits)
    delta = dequantize_innovation(qints, R_tree, bits)
    q_new, delta2, _, _ = quantize_roundtrip(g, qh, bits)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(delta2)):
        np.testing.assert_allclose(a, b, atol=1e-6)
    # codes fit in b bits
    for leaf in jax.tree.leaves(qints):
        assert leaf.dtype == jnp.uint8
        assert int(leaf.max()) <= 2 ** bits - 1


def test_zero_innovation_is_exact():
    g = {"w": jnp.ones((16,))}
    q_new, delta, R, err_sq = quantize_roundtrip(g, g, 4)
    assert float(R) == 0.0
    np.testing.assert_allclose(jax.tree.leaves(delta)[0], 0.0)
    np.testing.assert_allclose(float(err_sq), 0.0)


@hypothesis.given(
    arr=hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                max_side=32),
                   elements=st.floats(-1e4, 1e4, width=32)),
    bits=st.integers(1, 8),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_property_quantization_error(arr, bits):
    """Invariant: elementwise error <= tau*R for arbitrary finite inputs."""
    g = {"w": jnp.asarray(arr)}
    qh = jax.tree.map(jnp.zeros_like, g)
    q_new, _, R, _ = quantize_roundtrip(g, qh, bits)
    err = float(jnp.max(jnp.abs(g["w"] - q_new["w"])))
    assert err <= float(tau(bits) * R) * (1 + 1e-5) + 1e-5


@hypothesis.given(
    codes=hnp.arrays(np.uint8, st.integers(2, 64).filter(lambda n: n % 2 == 0),
                     elements=st.integers(0, 15)))
@hypothesis.settings(max_examples=40, deadline=None)
def test_property_pack_unpack_inverse(codes):
    packed = pack_nibbles(jnp.asarray(codes))
    assert packed.nbytes == codes.size // 2
    out = unpack_nibbles(packed)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_wire_cost_accounting():
    assert upload_bits(1000, 4) == 32 + 4000
    assert dense_bits(1000) == 32000


def test_tree_norms():
    g = {"a": jnp.array([3.0, -4.0]), "b": jnp.array([[0.0]])}
    assert float(tree_inf_norm(g)) == 4.0
    assert float(tree_sq_norm(g)) == 25.0
