"""Checkpoint save/load round-trips (repro/checkpoint/ckpt.py).

The contract the divergence watchdog (core/defense.py) leans on: a carry
saved mid-trajectory and restored into the same engine continues **bitwise
identically** to the uninterrupted run — CommState (qhat, clocks, eps-hat,
totals, estimator state, EF residual) and the participation state all ride
through the npz round-trip losslessly, for every strategy family.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import CriterionConfig, RoundEngine, StrategyConfig
from repro.core.engine import FullBatchSource, MinibatchSource

from test_engine_parity import quadratic_problem, regression_problem

CRIT = CriterionConfig(D=10, xi=0.08, t_bar=20)


def _engines():
    """(name, engine, params0) for the three strategy families: plain LAQ,
    stochastic SLAQ-WK2 + SVRG, and error-feedback top-k."""
    qloss, qp0, qdata = quadratic_problem()
    rloss, rp0, rdata = regression_problem()
    laq = StrategyConfig(kind="laq", bits=4, criterion=CRIT)
    wk2 = laq._replace(lazy_rule="lasg_wk2", grad_mode="svrg", svrg_period=7)
    ef = laq._replace(compressor="topk", compressor_k=0.5,
                      error_feedback=True)
    return [
        ("laq", RoundEngine(FullBatchSource(qloss, qdata), laq, alpha=0.3),
         qp0),
        ("slaq_wk2_svrg",
         RoundEngine(MinibatchSource(rloss, rdata, batch=4, seed=0), wk2,
                     alpha=0.1), rp0),
        ("ef_topk", RoundEngine(FullBatchSource(qloss, qdata), ef,
                                alpha=0.3), qp0),
    ]


@pytest.mark.parametrize("case", range(3), ids=["laq", "slaq_wk2_svrg",
                                                "ef_topk"])
def test_resume_is_bitwise_identical(case, tmp_path):
    name, eng, p0 = _engines()[case]
    path = str(tmp_path / f"{name}.npz")

    # the uninterrupted reference: 15 + 15 rounds in one carry chain
    carry = eng.init_carry(p0)
    carry_mid, rr_a = eng.run_from(carry, 15)
    save_checkpoint(path, carry_mid, 15)
    _, rr_ref = eng.run_from(carry_mid, 15)

    # restore into a *template* carry (fresh init => right structure/dtypes)
    template = eng.init_carry(p0)
    # the fresh template must not accidentally equal the mid-run state
    assert not np.array_equal(np.asarray(template[1].qhat["x" if case != 1
                                                          else "w"]),
                              np.asarray(carry_mid[1].qhat["x" if case != 1
                                                           else "w"]))
    restored, step = load_checkpoint(path, template)
    assert step == 15
    _, rr_resumed = eng.run_from(restored, 15)

    for field in ("loss", "grad_norm_sq", "cum_uploads", "cum_bits"):
        np.testing.assert_array_equal(np.asarray(getattr(rr_ref, field)),
                                      np.asarray(getattr(rr_resumed, field)),
                                      err_msg=f"{name}.{field}")
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), rr_ref.params, rr_resumed.params)


def test_dtype_preservation_and_bf16_tag(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16),
            "c": jnp.float32(2.5)}
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, tree, 7)
    out, step = load_checkpoint(path, tree)
    assert step == 7
    assert out["a"].dtype == jnp.int32 and out["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_load_errors_name_the_offending_keys(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"a": jnp.zeros((2,)), "b": jnp.ones((3,))}, 0)
    # template leaf absent from the file
    with pytest.raises(KeyError, match="missing from checkpoint"):
        load_checkpoint(path, {"a": jnp.zeros((2,)), "b": jnp.ones((3,)),
                               "c": jnp.zeros(())})
    # file entry the template does not consume
    with pytest.raises(KeyError, match="not consumed"):
        load_checkpoint(path, {"a": jnp.zeros((2,))})
    # shape mismatch
    with pytest.raises(ValueError, match="shape mismatch"):
        load_checkpoint(path, {"a": jnp.zeros((5,)), "b": jnp.ones((3,))})
    # not a checkpoint at all
    bogus = str(tmp_path / "bogus.npz")
    np.savez(bogus, x=np.zeros(3))
    with pytest.raises(KeyError, match="__step__"):
        load_checkpoint(bogus, {"x": jnp.zeros((3,))})
