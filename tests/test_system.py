"""End-to-end system tests.

1. Mini paper reproduction: multinomial logistic regression (strongly convex)
   on the synthetic MNIST-like mixture, M=10 workers — validates the paper's
   Table-2 ordering (bits: LAQ < QGD < GD, LAQ < LAG; rounds: lazy << dense)
   and equal final accuracy.
2. Sharded integration (subprocess with 8 forced host devices): LAQ train
   step on a (4 data x 2 model) mesh — loss decreases, packed wire is
   bit-identical to float wire on both wire backends (the fused request
   resolves per the jax version: honored on >= 0.5, warn-once reference
   downgrade on 0.4.x — pinned via ``step.wire_backend``), the adaptive
   fused pass-2 matches the reference adaptive trajectory, decode/prefill
   lower and compile, and the multi-pod (2,2,2) hierarchical mode runs.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CriterionConfig, StrategyConfig, run_gradient_based
from repro.data import classification_dataset, split_workers

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _logreg_setup(M=10):
    X, Y = classification_dataset(jax.random.PRNGKey(0), n_per_class=40)
    Xw, Yw = split_workers(X, Y, M)
    N = X.shape[0]
    lam = 0.01

    def loss_fn(params, data):
        x, y = data
        logits = x @ params["w"].T
        ce = -jnp.sum(y * jax.nn.log_softmax(logits, -1))
        return (ce + 0.5 * lam * jnp.sum(params["w"] ** 2)) / N

    params0 = {"w": jnp.zeros((10, 784))}
    return loss_fn, params0, (Xw, Yw), (X, Y)


def _accuracy(params, X, Y):
    pred = jnp.argmax(X @ params["w"].T, -1)
    return float(jnp.mean((pred == jnp.argmax(Y, -1)).astype(jnp.float32)))


def test_paper_repro_gradient_based_ordering():
    loss_fn, p0, workers, full = _logreg_setup()
    crit = CriterionConfig(D=10, xi=0.8 / 10, t_bar=100)
    out = {}
    for kind in ("gd", "qgd", "lag", "laq"):
        cfg = StrategyConfig(kind=kind, bits=4, criterion=crit)
        out[kind] = run_gradient_based(loss_fn, p0, workers, cfg,
                                       steps=300, alpha=2.0)
    accs = {k: _accuracy(r.params, *full) for k, r in out.items()}
    bits = {k: float(r.cum_bits[-1]) for k, r in out.items()}
    rounds = {k: int(r.cum_uploads[-1]) for k, r in out.items()}
    # Table 2 qualitative claims
    assert bits["laq"] < bits["lag"], (bits)
    assert bits["laq"] < bits["qgd"] < bits["gd"], (bits)
    assert rounds["laq"] < 0.5 * rounds["qgd"], (rounds)
    assert rounds["lag"] < 0.5 * rounds["gd"], (rounds)
    # same accuracy across methods (paper: identical accuracy column)
    assert max(accs.values()) - min(accs.values()) < 0.02, accs
    # linear convergence of the loss residual for LAQ (Theorem 1)
    resid = np.asarray(out["laq"].loss) - float(out["gd"].loss[-1]) + 1e-12
    y = np.log(np.maximum(resid[10:250], 1e-12))
    slope = np.polyfit(np.arange(y.size), y, 1)[0]
    assert slope < -0.005, slope


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config
from repro.core.adaptive import BitSchedule
from repro.core.strategy import StrategyConfig
from repro.optim import sgd
from repro.launch.train import (make_train_step, train_state_specs,
                                init_train_state)
from repro.launch.serve import serve_specs, make_decode_step
from repro.data import synthetic_lm_batch

out = {}
cfg = smoke_config(get_config("stablelm-1.6b"))
strategy = StrategyConfig(kind="laq", bits=4, per_leaf_radius=True)
opt = sgd()

# --- single-pod flat mode -------------------------------------------------
mesh = jax.make_mesh((4, 2), ("data", "model"))
wa = ("data",)
batch = synthetic_lm_batch(jax.random.PRNGKey(1), 8, 64, cfg.vocab)
batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))

def fresh(strat=strategy):
    s = init_train_state(jax.random.PRNGKey(0), cfg, mesh, strat, opt, wa)
    sp = train_state_specs(cfg, mesh, strat, opt, wa)
    return jax.tree.map(lambda x, spc: jax.device_put(x, spc.sharding), s, sp)

def max_param_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(
        x.astype(jnp.float32) - y.astype(jnp.float32)))), a.params, b.params)))

losses = []
state = fresh()
jstep = jax.jit(make_train_step(cfg, mesh, strategy, opt, lr=1e-2,
                                worker_axes=wa, wire="float"))
for _ in range(6):
    state, m = jstep(state, batch)
    losses.append(float(m.loss))
out["losses"] = losses

s1, s2 = fresh(), fresh()
jp = jax.jit(make_train_step(cfg, mesh, strategy, opt, lr=1e-2,
                             worker_axes=wa, wire="packed"))
for _ in range(3):
    s1, m1 = jstep(s1, batch)
    s2, m2 = jp(s2, batch)
out["packed_max_diff"] = max_param_diff(s1, s2)

# fused wire backend through the sharded step: jax >= 0.5 honors the
# request (compat.SUPPORTS_PALLAS_PARTIAL_AUTO), 0.4.x downgrades to the
# bit-identical reference pipeline with a warn-once log — either way the
# resolved name is exposed on the step fn, and on CPU hosts the fused
# backend runs the shared reference expressions, so parity is bitwise
from repro import compat
fu = strategy._replace(wire_backend="fused")
step_fu = make_train_step(cfg, mesh, fu, opt, lr=1e-2, worker_axes=wa,
                          wire="packed")
out["fused_resolved_backend"] = step_fu.wire_backend
out["fused_expected_backend"] = (
    "fused" if compat.SUPPORTS_PALLAS_PARTIAL_AUTO else "reference")
jff = jax.jit(make_train_step(cfg, mesh, fu, opt, lr=1e-2,
                              worker_axes=wa, wire="float"))
jfp = jax.jit(step_fu)
f1, f2 = fresh(fu), fresh(fu)
for _ in range(3):
    f1, _ = jff(f1, batch)
    f2, _ = jfp(f2, batch)
out["fused_float_max_diff"] = max_param_diff(f1, s1)
out["fused_packed_max_diff"] = max_param_diff(f2, s2)

# adaptive bit-width (A-LAQ): packed wire must stay bit-identical to float
ad = strategy._replace(bit_schedule=BitSchedule(kind="radius", grid=(2, 4, 8),
                                                thresholds=(1e-3, 1e-2)))
a1, a2 = fresh(ad), fresh(ad)
jaf = jax.jit(make_train_step(cfg, mesh, ad, opt, lr=1e-2,
                              worker_axes=wa, wire="float"))
jap = jax.jit(make_train_step(cfg, mesh, ad, opt, lr=1e-2,
                              worker_axes=wa, wire="packed"))
for _ in range(3):
    a1, _ = jaf(a1, batch)
    a2, _ = jap(a2, batch)
out["adaptive_packed_max_diff"] = max_param_diff(a1, a2)

# adaptive + fused: the width-grid-unrolled pass-2 pipeline through the
# sharded packed wire matches the reference adaptive run bitwise
adf = ad._replace(wire_backend="fused")
af = fresh(adf)
jadf = jax.jit(make_train_step(cfg, mesh, adf, opt, lr=1e-2,
                               worker_axes=wa, wire="packed"))
for _ in range(3):
    af, _ = jadf(af, batch)
out["adaptive_fused_packed_max_diff"] = max_param_diff(af, a2)

# constant schedule routes to the fixed-bit path: exact match with bits=4
cs = strategy._replace(bits=7, bit_schedule=BitSchedule(kind="constant", bits=4))
c2 = fresh(cs)
jcp = jax.jit(make_train_step(cfg, mesh, cs, opt, lr=1e-2,
                              worker_axes=wa, wire="packed"))
for _ in range(3):
    c2, _ = jcp(c2, batch)
out["const_packed_max_diff"] = max_param_diff(s2, c2)

# variance-aware lazy rules (core/lazy_rules.py) + scale-free rel-mode
# adaptive anchor: the new CommState fields (lazy estimator state, R_anchor)
# thread through the sharded step on both wires
wk = strategy._replace(lazy_rule="lasg_wk")
ps = strategy._replace(
    lazy_rule="lasg_ps",
    bit_schedule=BitSchedule(kind="radius", grid=(2, 4, 8),
                             threshold_mode="rel", thresholds=(0.05, 0.5)))
w1 = fresh(wk)
jwk = jax.jit(make_train_step(cfg, mesh, wk, opt, lr=1e-2,
                              worker_axes=wa, wire="float"))
wl = []
for _ in range(4):
    w1, m = jwk(w1, batch)
    wl.append(float(m.loss))
out["wk_losses"] = wl
out["wk_sigma_hat_max"] = float(jnp.max(w1.comm.lazy.sigma_hat_sq))
p1 = fresh(ps)
jps = jax.jit(make_train_step(cfg, mesh, ps, opt, lr=1e-2,
                              worker_axes=wa, wire="packed"))
pl = []
for _ in range(4):
    p1, m = jps(p1, batch)
    pl.append(float(m.loss))
out["ps_losses"] = pl
out["ps_anchor_min"] = float(jnp.min(p1.comm.R_anchor))
out["ps_theta_last_set"] = float(max(jax.tree.leaves(jax.tree.map(
    lambda l: float(jnp.max(jnp.abs(l))), p1.comm.lazy.theta_last))))

# wk2 same-sample rule (second backprop at the stale iterate) + streaming
# svrg anchor + 1/t stepsize schedule: the PR-4 CommState fields (svrg) and
# the scheduled lr thread through the mesh on the packed wire
from repro.core.adaptive import EtaSchedule
vr = strategy._replace(lazy_rule="lasg_wk2", grad_mode="svrg", svrg_period=2,
                       eta_schedule=EtaSchedule(kind="inv_t", t0=10.0))
v1 = fresh(vr)
jvr = jax.jit(make_train_step(cfg, mesh, vr, opt, lr=1e-2,
                              worker_axes=wa, wire="packed"))
vl = []
for _ in range(4):
    v1, m = jvr(v1, batch)
    vl.append(float(m.loss))
out["vr_losses"] = vl
out["vr_theta_last_set"] = float(max(jax.tree.leaves(jax.tree.map(
    lambda l: float(jnp.max(jnp.abs(l))), v1.comm.lazy.theta_last))))
out["vr_mu_set"] = float(max(jax.tree.leaves(jax.tree.map(
    lambda l: float(jnp.max(jnp.abs(l))), v1.comm.svrg.mu_anchor))))

# upload defense (PR-7, core/defense.py): DefenseState threads through the
# sharded step on both wires; at fault rate 0 validation+gate must be a
# bitwise no-op vs the undefended run, and float vs packed stay identical
from repro.core.defense import DefenseConfig
df = strategy._replace(defense=DefenseConfig(validate=True, gate_mult=6.0))
d1, d2 = fresh(df), fresh(df)
jdf = jax.jit(make_train_step(cfg, mesh, df, opt, lr=1e-2,
                              worker_axes=wa, wire="float"))
jdp = jax.jit(make_train_step(cfg, mesh, df, opt, lr=1e-2,
                              worker_axes=wa, wire="packed"))
s0 = fresh()
for _ in range(3):
    d1, m = jdf(d1, batch)
    d2, _ = jdp(d2, batch)
    s0, _ = jstep(s0, batch)
out["defense_noop_max_diff"] = max_param_diff(d1, s0)
out["defense_packed_max_diff"] = max_param_diff(d1, d2)
out["defense_rejects"] = int(jnp.sum(d1.comm.defense.rejects))

# partial participation (PR-5 round engine): the replicated cohort mask is
# indexed per shard by the worker-index input (axis_index would lower to
# PartitionId, which the 0.4.x partial-auto partitioner rejects)
from repro.core.engine import participation_mask
pp = strategy._replace(participation="bernoulli", participation_p=0.5)
p2 = fresh(pp)
jpp = jax.jit(make_train_step(cfg, mesh, pp, opt, lr=1e-2,
                              worker_axes=wa, wire="float"))
pp_ups = []
for _ in range(4):
    p2, m = jpp(p2, batch)
    pp_ups.append(int(m.uploads))
out["pp_uploads"] = pp_ups
out["pp_cohorts"] = [int(participation_mask(pp, k, 4).sum())
                     for k in range(4)]

params_s, cache_s, tokens_s = serve_specs(cfg, mesh, batch=8, seq_len=128)
c = jax.jit(make_decode_step(cfg)).lower(params_s, cache_s, tokens_s).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca   # jax<0.5 returns [dict]
out["decode_flops"] = float(ca.get("flops", -1))

# --- multi-pod hierarchical mode -------------------------------------------
mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
wa2 = ("pod",)
specs2 = train_state_specs(cfg, mesh2, strategy, opt, wa2)
state2 = init_train_state(jax.random.PRNGKey(0), cfg, mesh2, strategy, opt, wa2)
state2 = jax.tree.map(lambda x, sp: jax.device_put(x, sp.sharding), state2, specs2)
batch2 = jax.device_put(synthetic_lm_batch(jax.random.PRNGKey(1), 8, 64, cfg.vocab),
                        NamedSharding(mesh2, P(("pod", "data"), None)))
jstep2 = jax.jit(make_train_step(cfg, mesh2, strategy, opt, lr=1e-2,
                                 worker_axes=wa2, wire="packed"))
l2 = []
for _ in range(4):
    state2, m = jstep2(state2, batch2)
    l2.append(float(m.loss))
out["pod_losses"] = l2
out["pod_uploads"] = int(m.uploads)
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_integration_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["losses"][-1] < out["losses"][0], out["losses"]
    assert out["packed_max_diff"] == 0.0, out
    assert out["adaptive_packed_max_diff"] == 0.0, out
    assert out["const_packed_max_diff"] == 0.0, out
    # fused wire backend on the mesh: the resolved backend matches this
    # jax's capability (honored on >= 0.5, warn-once reference downgrade on
    # 0.4.x), and fused runs are bitwise-identical to the reference wire —
    # fixed-width float and packed, and the adaptive packed trajectory
    assert out["fused_resolved_backend"] == out["fused_expected_backend"], out
    assert out["fused_float_max_diff"] == 0.0, out
    assert out["fused_packed_max_diff"] == 0.0, out
    assert out["adaptive_fused_packed_max_diff"] == 0.0, out
    # LASG rules on the mesh: runs stay finite and learn; the WK variance
    # estimate was frozen at an upload; the PS stale-iterate snapshot and
    # the rel-mode anchor were populated by the bootstrap round
    assert np.all(np.isfinite(out["wk_losses"])), out["wk_losses"]
    assert out["wk_losses"][-1] < out["wk_losses"][0], out["wk_losses"]
    assert out["wk_sigma_hat_max"] > 0.0, out
    assert np.all(np.isfinite(out["ps_losses"])), out["ps_losses"]
    assert out["ps_anchor_min"] > 0.0, out
    assert out["ps_theta_last_set"] > 0.0, out
    # defense on a clean run through the mesh: bitwise no-op vs undefended,
    # float/packed identical, nothing rejected
    assert out["defense_noop_max_diff"] == 0.0, out
    assert out["defense_packed_max_diff"] == 0.0, out
    assert out["defense_rejects"] == 0, out
    # WK2 + streaming svrg + 1/t schedule on the mesh: finite losses, the
    # stale-iterate snapshot and the svrg anchor's mu were both populated
    assert np.all(np.isfinite(out["vr_losses"])), out["vr_losses"]
    assert out["vr_theta_last_set"] > 0.0, out
    assert out["vr_mu_set"] > 0.0, out
    # participation on the mesh: the bootstrap round uploads exactly the
    # cohort (clocks start at t_bar), later rounds at most the cohort
    assert out["pp_uploads"][0] == out["pp_cohorts"][0], out
    assert all(u <= c for u, c in zip(out["pp_uploads"],
                                      out["pp_cohorts"])), out
    assert out["decode_flops"] > 0
    assert out["pod_losses"][-1] < out["pod_losses"][0], out["pod_losses"]
    assert 0 <= out["pod_uploads"] <= 2
