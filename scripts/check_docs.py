#!/usr/bin/env python
"""Docs gate (CI `docs` job): keeps README and docs/ from rotting.

1. Extracts every ```python fenced block from README.md and executes it
   (repo root cwd, PYTHONPATH=src) — the quickstart snippet must keep
   running against the current API.
2. Checks intra-repo markdown links in README.md and docs/*.md: every
   relative `[text](path)` target must exist (http(s)/mailto links are
   skipped, pure `#anchor` links too).

Exit code 0 iff both pass.

    python scripts/check_docs.py
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

FENCE_RE = re.compile(r"```python\n(.*?)```", re.S)
# [text](target) — excluding images is unnecessary (targets must exist
# either way); inline code spans don't match because of the bracket.
LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")


def run_readme_snippets() -> list[str]:
    errors = []
    blocks = FENCE_RE.findall((ROOT / "README.md").read_text())
    if not blocks:
        return ["README.md has no ```python quickstart block to execute"]
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for i, block in enumerate(blocks):
        with tempfile.NamedTemporaryFile("w", suffix=f"_readme_{i}.py",
                                         delete=False) as f:
            f.write(block)
            path = f.name
        try:
            proc = subprocess.run([sys.executable, path], cwd=ROOT, env=env,
                                  capture_output=True, text=True, timeout=600)
            if proc.returncode != 0:
                errors.append(
                    f"README.md python block #{i + 1} failed "
                    f"(exit {proc.returncode}):\n{proc.stdout}{proc.stderr}")
            else:
                sys.stderr.write(f"# README block #{i + 1} ok:\n"
                                 + proc.stdout)
        finally:
            os.unlink(path)
    return errors


def check_links() -> list[str]:
    errors = []
    for md in DOC_FILES:
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not (md.parent / rel).resolve().exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> None:
    errors = check_links()
    errors += run_readme_snippets()
    for e in errors:
        print(f"[FAIL] {e}")
    n_links = sum(len(LINK_RE.findall(p.read_text())) for p in DOC_FILES)
    print(f"# checked {len(DOC_FILES)} doc files, {n_links} links; "
          f"{len(errors)} problem(s)")
    raise SystemExit(1 if errors else 0)


if __name__ == "__main__":
    main()
