#!/usr/bin/env python
"""Benchmark-artifact gate: schema-validate every BENCH_*.json at the repo
root (the per-PR artifacts CI uploads — BENCH_wire.json from the wire
microbenchmark, BENCH_ef.json from the EF frontier, BENCH_faults.json
from the fault frontier, BENCH_lm.json from the LM frontier,
BENCH_serve.json from the serving frontier).  The REQUIRED set makes a
*missing* artifact fail too: a benchmark that silently stopped writing its
file must not read as green.

Every artifact must be a JSON object with

* ``rows``   — a non-empty list of flat row objects (scalar/str/None
  values only: the artifacts diff cleanly and plot without unpickling);
* ``checks`` — a dict of check-name -> true / false / null (null = the
  check was skipped in this variant, e.g. a --tiny run).

A ``false`` check is also a failure here: a committed artifact recording a
failing claim must fail the gate, not ride along silently.

Exit code 0 iff every artifact validates.

    python scripts/check_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCALARS = (int, float, str, bool, type(None))
REQUIRED = ("BENCH_wire.json", "BENCH_ef.json", "BENCH_faults.json",
            "BENCH_lm.json", "BENCH_serve.json")


def validate(path: pathlib.Path) -> list[str]:
    errors = []
    try:
        doc = json.loads(path.read_text())
    except ValueError as e:
        return [f"{path.name}: not valid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object"]

    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{path.name}: 'rows' must be a non-empty list")
    else:
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                errors.append(f"{path.name}: rows[{i}] is not an object")
            elif bad := [k for k, v in row.items()
                         if not isinstance(v, SCALARS)]:
                errors.append(f"{path.name}: rows[{i}] has non-scalar "
                              f"fields {bad}")

    checks = doc.get("checks")
    if not isinstance(checks, dict) or not checks:
        errors.append(f"{path.name}: 'checks' must be a non-empty object")
    else:
        for name, v in checks.items():
            if not (v is None or isinstance(v, bool)):
                errors.append(f"{path.name}: checks[{name!r}] must be "
                              f"true/false/null, got {v!r}")
            elif v is False:
                errors.append(f"{path.name}: checks[{name!r}] is false — "
                              f"artifact records a failing claim")
    return errors


def main() -> int:
    paths = sorted(ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json artifacts at repo root",
              file=sys.stderr)
        return 1
    errors = [e for p in paths for e in validate(p)]
    names = {p.name for p in paths}
    errors += [f"required artifact {r} is missing"
               for r in REQUIRED if r not in names]
    for e in errors:
        print(f"check_bench: {e}", file=sys.stderr)
    print(f"check_bench: {len(paths)} artifact(s), "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
