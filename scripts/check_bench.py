#!/usr/bin/env python
"""Benchmark-artifact gate: schema-validate every BENCH_*.json at the repo
root (the per-PR artifacts CI uploads — BENCH_wire.json from the wire
microbenchmark, BENCH_ef.json from the EF frontier, BENCH_faults.json
from the fault frontier, BENCH_lm.json from the LM frontier,
BENCH_serve.json from the serving frontier).  The REQUIRED set makes a
*missing* artifact fail too: a benchmark that silently stopped writing its
file must not read as green.

Every artifact must be a JSON object with

* ``rows``   — a non-empty list of flat row objects (scalar/str/None
  values only: the artifacts diff cleanly and plot without unpickling);
* ``checks`` — a dict of check-name -> true / false / null (null = the
  check was skipped in this variant, e.g. a --tiny run).

A ``false`` check is also a failure here: a committed artifact recording a
failing claim must fail the gate, not ride along silently.

``BENCH_wire.json`` additionally carries per-row lowering + roofline
schema (the perf-trajectory contract): every row must record which
lowering the fused pipeline measured (``fused_lowering``: "pallas" or
"jnp-flat") and positive compiled cost-analysis roofline terms
(``roofline_flops``, ``roofline_hbm_bytes``, a valid
``roofline_bottleneck``).  On a Pallas-capable backend (``jax_backend``
!= "cpu") a row reporting "jnp-flat" fails the gate: the artifact would
be silently measuring the fallback lowering on hardware where the
kernels should run.

Exit code 0 iff every artifact validates.

    python scripts/check_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCALARS = (int, float, str, bool, type(None))
REQUIRED = ("BENCH_wire.json", "BENCH_ef.json", "BENCH_faults.json",
            "BENCH_lm.json", "BENCH_serve.json")


def validate(path: pathlib.Path) -> list[str]:
    errors = []
    try:
        doc = json.loads(path.read_text())
    except ValueError as e:
        return [f"{path.name}: not valid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object"]

    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{path.name}: 'rows' must be a non-empty list")
    else:
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                errors.append(f"{path.name}: rows[{i}] is not an object")
            elif bad := [k for k, v in row.items()
                         if not isinstance(v, SCALARS)]:
                errors.append(f"{path.name}: rows[{i}] has non-scalar "
                              f"fields {bad}")

    checks = doc.get("checks")
    if not isinstance(checks, dict) or not checks:
        errors.append(f"{path.name}: 'checks' must be a non-empty object")
    else:
        for name, v in checks.items():
            if not (v is None or isinstance(v, bool)):
                errors.append(f"{path.name}: checks[{name!r}] must be "
                              f"true/false/null, got {v!r}")
            elif v is False:
                errors.append(f"{path.name}: checks[{name!r}] is false — "
                              f"artifact records a failing claim")

    if path.name == "BENCH_wire.json" and isinstance(rows, list):
        errors += validate_wire(path.name, doc, rows)
    return errors


_BOTTLENECKS = ("compute", "memory", "collective")


def validate_wire(name: str, doc: dict, rows: list) -> list[str]:
    """BENCH_wire.json-specific schema: per-row lowering + roofline terms."""
    errors = []
    backend = doc.get("jax_backend")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        lowering = row.get("fused_lowering")
        if lowering not in ("pallas", "jnp-flat"):
            errors.append(f"{name}: rows[{i}] missing/invalid "
                          f"'fused_lowering' (got {lowering!r})")
        elif backend != "cpu" and lowering == "jnp-flat":
            errors.append(f"{name}: rows[{i}] measured the jnp-flat "
                          f"fallback on Pallas-capable backend "
                          f"{backend!r} — kernels did not lower")
        for key in ("roofline_flops", "roofline_hbm_bytes"):
            v = row.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v <= 0:
                errors.append(f"{name}: rows[{i}] needs positive "
                              f"{key!r}, got {v!r}")
        if row.get("roofline_bottleneck") not in _BOTTLENECKS:
            errors.append(f"{name}: rows[{i}] 'roofline_bottleneck' must "
                          f"be one of {_BOTTLENECKS}, got "
                          f"{row.get('roofline_bottleneck')!r}")
    return errors


def main() -> int:
    paths = sorted(ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json artifacts at repo root",
              file=sys.stderr)
        return 1
    errors = [e for p in paths for e in validate(p)]
    names = {p.name for p in paths}
    errors += [f"required artifact {r} is missing"
               for r in REQUIRED if r not in names]
    for e in errors:
        print(f"check_bench: {e}", file=sys.stderr)
    print(f"check_bench: {len(paths)} artifact(s), "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
